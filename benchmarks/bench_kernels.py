"""Kernel microbenchmark: Pallas SCD (interpret on CPU; compiled on TPU)
vs the pure-jnp oracle, timed under the harness's warmup/repeat/min
discipline."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import TimingPolicy, time_callable
from repro.kernels import scd_steps_kernel, scd_steps_ref


@benchmark("kernels", figures="§kernels",
           description="Pallas SCD kernel vs jnp oracle microbench")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    reps = ctx.repeats or max(wl.reps, 2)
    policy = TimingPolicy(warmup=1, reps=reps)
    rng = np.random.default_rng(ctx.seed)
    rows, timings, counters = [], {}, {}
    for (m, n, H) in wl.kernel_shapes:
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        colsq = jnp.sum(A * A, 0)
        alpha = jnp.zeros(n, jnp.float32)
        w = jnp.asarray(rng.standard_normal(m), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, H), jnp.int32)
        kw = dict(sigma=8.0, lam=1.0, eta=1.0)
        t_ref = time_callable(scd_steps_ref, A, colsq, alpha, w, idx,
                              policy=policy, **kw)
        t_ker = time_callable(scd_steps_kernel, A, colsq, alpha, w, idx,
                              policy=policy, **kw)
        flops = 4.0 * m * H  # dot + axpy per step
        for label, t in (("scd_ref", t_ref), ("scd_pallas_interp", t_ker)):
            rows.append({"name": f"{label}_m{m}_H{H}",
                         "us_per_call": round(t * 1e6, 1),
                         "derived": f"{flops / t / 1e9:.2f}GFLOP/s"})
            timings[f"{label}_m{m}_H{H}"] = t
            counters[f"gflops_{label}_m{m}_H{H}"] = round(flops / t / 1e9, 3)
    notes = ["pallas numbers are interpret-mode (CPU emulation) — "
             "correctness benchmark, not TPU speed"]
    return {"params": {"shapes": [list(s) for s in wl.kernel_shapes],
                       "reps": reps},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    common.emit("kernels", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
