"""Kernel microbenchmark: the Pallas kernels (interpret on CPU;
compiled on TPU) vs their pure-jnp oracles, timed under the harness's
warmup/repeat/min discipline — the SCD local solver and the fused
quantize+pack wire encoders (int8 and packed int4), whose interpret-
mode outputs are asserted bit-identical to the codec oracle so the
kernel's cost AND correctness both show up in the trajectory."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import TimingPolicy, time_callable
from repro.kernels import (quantize_pack_int2, quantize_pack_int2_ref,
                           quantize_pack_int4, quantize_pack_int4_ref,
                           quantize_pack_int8, quantize_pack_int8_ref,
                           scd_steps_kernel, scd_steps_ref)


@benchmark("kernels", figures="§kernels",
           description="Pallas SCD kernel vs jnp oracle microbench")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    reps = ctx.repeats or max(wl.reps, 2)
    policy = TimingPolicy(warmup=1, reps=reps)
    rng = np.random.default_rng(ctx.seed)
    rows, timings, counters = [], {}, {}
    for (m, n, H) in wl.kernel_shapes:
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        colsq = jnp.sum(A * A, 0)
        alpha = jnp.zeros(n, jnp.float32)
        w = jnp.asarray(rng.standard_normal(m), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, H), jnp.int32)
        kw = dict(sigma=8.0, lam=1.0, eta=1.0)
        t_ref = time_callable(scd_steps_ref, A, colsq, alpha, w, idx,
                              policy=policy, **kw)
        t_ker = time_callable(scd_steps_kernel, A, colsq, alpha, w, idx,
                              policy=policy, **kw)
        flops = 4.0 * m * H  # dot + axpy per step
        for label, t in (("scd_ref", t_ref), ("scd_pallas_interp", t_ker)):
            rows.append({"name": f"{label}_m{m}_H{H}",
                         "us_per_call": round(t * 1e6, 1),
                         "derived": f"{flops / t / 1e9:.2f}GFLOP/s"})
            timings[f"{label}_m{m}_H{H}"] = t
            counters[f"gflops_{label}_m{m}_H{H}"] = round(flops / t / 1e9, 3)
    # fused quantize+pack: oracle (jitted jnp) vs Pallas interpret, with
    # the interpret output asserted bit-identical to the oracle — the
    # same contract the comm codecs rely on for the compressed exchange
    quant = {"quant_int8": (jax.jit(quantize_pack_int8_ref),
                            quantize_pack_int8),
             "quant_int4": (jax.jit(quantize_pack_int4_ref),
                            quantize_pack_int4),
             "quant_int2": (jax.jit(quantize_pack_int2_ref),
                            quantize_pack_int2)}
    for L in wl.quant_lengths:
        dv = jnp.asarray(rng.standard_normal(L), jnp.float32)
        for name, (ref_fn, ker_fn) in quant.items():
            p_ref, s_ref = ref_fn(dv)
            p_ker, s_ker = ker_fn(dv)
            assert (np.array_equal(np.asarray(p_ref), np.asarray(p_ker))
                    and float(s_ref) == float(s_ker)), (
                f"{name} L={L}: Pallas interpret output is not "
                f"bit-identical to the jnp oracle")
            t_ref = time_callable(ref_fn, dv, policy=policy)
            t_ker = time_callable(ker_fn, dv, policy=policy)
            wire = p_ref.size * p_ref.dtype.itemsize + 4
            for label, t in ((f"{name}_ref", t_ref),
                             (f"{name}_pallas_interp", t_ker)):
                rows.append({"name": f"{label}_L{L}",
                             "us_per_call": round(t * 1e6, 1),
                             "derived": f"{4 * L / wire:.2f}x smaller"})
                timings[f"{label}_L{L}"] = t
            counters[f"wire_bytes_{name}_L{L}"] = wire
    notes = ["pallas numbers are interpret-mode (CPU emulation) — "
             "correctness benchmark, not TPU speed",
             "quantize+pack interpret outputs asserted bit-identical "
             "to the codec oracle at every length"]
    return {"params": {"shapes": [list(s) for s in wl.kernel_shapes],
                       "quant_lengths": list(wl.quant_lengths),
                       "reps": reps},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    common.emit("kernels", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
