"""Kernel microbenchmark: the Pallas kernels (interpret on CPU;
compiled on TPU) vs their pure-jnp oracles, timed under the harness's
warmup/repeat/min discipline — the tiled SCD local solver, the fused
quantize+pack wire encoders (int8 / packed int4 / packed int2), the
fused decode+mean gather-side reducers, and the fused top-k select.
Every fused kernel's interpret-mode output is asserted bit-identical
to its codec oracle, so cost AND correctness both show up in the
trajectory.

Each Pallas cell also reports its roofline position: ``model_flops_*``
and ``model_bytes_*`` are machine-independent operation/traffic models
(exact-gated in CI under the ``model_`` prefix — drift means the
kernel's work model changed, not that the host got slower), and
``roofline_flops_frac_*`` / ``roofline_bw_frac_*`` divide the achieved
rates by the TPU v5e chip peaks in ``repro.launch.mesh``. On the CI
host the fractions are interpret-mode CPU numbers (tiny by
construction); on TPU they read directly as roofline fractions.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import TimingPolicy, time_callable
from repro.comm.codec import get_codec
from repro.kernels import (decode_mean_int2, decode_mean_int4,
                           decode_mean_int8, decode_stacked_ref,
                           quantize_pack_int2, quantize_pack_int2_ref,
                           quantize_pack_int4, quantize_pack_int4_ref,
                           quantize_pack_int8, quantize_pack_int8_ref,
                           scd_steps_kernel, scd_steps_ref, topk_select,
                           topk_select_ref)
from repro.launch.mesh import kernel_roofline

# decoded-elements cost factor: unpack ops per element before the
# scale multiply (int8 converts only; int4/int2 mask+shift+bias)
_UNPACK_OPS = {"int8": 1, "int4": 3, "int2": 3}


def _roofline(counters: dict, cell: str, flops: int, nbytes: int,
              t: float) -> None:
    """Attach the exact work model and the achieved roofline fractions
    of one Pallas cell to the counter dict."""
    counters[f"model_flops_{cell}"] = int(flops)
    counters[f"model_bytes_{cell}"] = int(nbytes)
    rl = kernel_roofline(float(flops), float(nbytes), t)
    counters[f"roofline_flops_frac_{cell}"] = rl["flops_frac_of_peak"]
    counters[f"roofline_bw_frac_{cell}"] = rl["bw_frac_of_hbm"]


@benchmark("kernels", figures="§kernels",
           description="Pallas SCD kernel vs jnp oracle microbench")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    reps = ctx.repeats or max(wl.reps, 2)
    policy = TimingPolicy(warmup=1, reps=reps)
    rng = np.random.default_rng(ctx.seed)
    rows, timings, counters = [], {}, {}
    # -- tiled SCD: lane-tiled Pallas kernel vs the jnp reference loop.
    # The rework streams (h_blk, S, 128) column tiles through VMEM, so
    # the kernel must hold its own against the oracle even in interpret
    # mode: the smoke tier pins >= 0.9x ref GFLOP/s on the largest-m
    # shape (the one where the old (1, m) row layout wasted 7/8 of
    # every f32 sublane tile).
    big_m = max(wl.kernel_shapes, key=lambda s: s[0])
    for (m, n, H) in wl.kernel_shapes:
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        colsq = jnp.sum(A * A, 0)
        alpha = jnp.zeros(n, jnp.float32)
        w = jnp.asarray(rng.standard_normal(m), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, H), jnp.int32)
        kw = dict(sigma=8.0, lam=1.0, eta=1.0)
        # the asserted shape gets a deeper min-of-reps so the 0.9x gate
        # measures the kernel, not scheduler jitter on a busy CI host
        pol = (TimingPolicy(warmup=2, reps=max(reps, 5))
               if ctx.tier == "smoke" and (m, n, H) == big_m else policy)
        t_ref = time_callable(scd_steps_ref, A, colsq, alpha, w, idx,
                              policy=pol, **kw)
        t_ker = time_callable(scd_steps_kernel, A, colsq, alpha, w, idx,
                              policy=pol, **kw)
        flops = 4 * m * H  # dot + axpy per step
        # column stream + per-step scalars (csq, dinv, thr, idx) +
        # alpha read/write + w read / rho write
        scd_bytes = 4 * H * m + 16 * H + 8 * n + 8 * m
        for label, t in (("scd_ref", t_ref), ("scd_pallas_interp", t_ker)):
            cell = f"{label}_m{m}_H{H}"
            rows.append({"name": cell,
                         "us_per_call": round(t * 1e6, 1),
                         "derived": f"{flops / t / 1e9:.2f}GFLOP/s"})
            timings[cell] = t
            counters[f"gflops_{cell}"] = round(flops / t / 1e9, 3)
        _roofline(counters, f"scd_pallas_interp_m{m}_H{H}",
                  flops, scd_bytes, t_ker)
        ratio = t_ref / t_ker
        counters[f"scd_ratio_vs_ref_m{m}_H{H}"] = round(ratio, 3)
        if ctx.tier == "smoke" and (m, n, H) == big_m:
            assert ratio >= 0.9, (
                f"tiled SCD kernel at (m={m}, n={n}, H={H}) runs at "
                f"{ratio:.2f}x the reference GFLOP/s — below the 0.9x "
                f"floor the rework pins")
    # -- fused quantize+pack: oracle (jitted jnp) vs Pallas interpret,
    # with the interpret output asserted bit-identical to the oracle —
    # the same contract the comm codecs rely on for the compressed
    # exchange
    quant = {"quant_int8": (jax.jit(quantize_pack_int8_ref),
                            quantize_pack_int8, 8),
             "quant_int4": (jax.jit(quantize_pack_int4_ref),
                            quantize_pack_int4, 4),
             "quant_int2": (jax.jit(quantize_pack_int2_ref),
                            quantize_pack_int2, 2)}
    for L in wl.quant_lengths:
        dv = jnp.asarray(rng.standard_normal(L), jnp.float32)
        for name, (ref_fn, ker_fn, bits) in quant.items():
            p_ref, s_ref = ref_fn(dv)
            p_ker, s_ker = ker_fn(dv)
            assert (np.array_equal(np.asarray(p_ref), np.asarray(p_ker))
                    and float(s_ref) == float(s_ker)), (
                f"{name} L={L}: Pallas interpret output is not "
                f"bit-identical to the jnp oracle")
            t_ref = time_callable(ref_fn, dv, policy=policy)
            t_ker = time_callable(ker_fn, dv, policy=policy)
            wire = p_ref.size * p_ref.dtype.itemsize + 4
            for label, t in ((f"{name}_ref", t_ref),
                             (f"{name}_pallas_interp", t_ker)):
                rows.append({"name": f"{label}_L{L}",
                             "us_per_call": round(t * 1e6, 1),
                             "derived": f"{4 * L / wire:.2f}x smaller"})
                timings[f"{label}_L{L}"] = t
            counters[f"wire_bytes_{name}_L{L}"] = wire
            # absmax + scale + round/clip per element, then pack:
            # (spe - 1) shift+or per packed byte
            spe = 8 // bits
            q_flops = 6 * L + (spe - 1) * 2 * math.ceil(L / spe)
            _roofline(counters, f"{name}_pallas_interp_L{L}",
                      q_flops, 4 * L + wire, t_ker)
    # -- fused decode+mean: the gather-side kernels behind
    # decode_stacked_mean, against the sequential jnp oracle in
    # repro.kernels.ref — the contract that closed the f32-intermediate
    # findings. Bit-identity is asserted jitted-vs-jitted at every
    # (K, L) cell.
    dec = {"decode_mean_int8": ("int8", decode_mean_int8),
           "decode_mean_int4": ("int4", decode_mean_int4),
           "decode_mean_int2": ("int2", decode_mean_int2)}
    K = wl.K
    for L in wl.quant_lengths:
        for name, (codec_name, ker_fn) in dec.items():
            codec = get_codec(codec_name)
            parts = [codec.encode(
                jnp.asarray(rng.standard_normal(L), jnp.float32))
                for _ in range(K)]
            payload = jnp.stack([p for p, _ in parts])
            scales = jnp.stack([s for _, s in parts])
            ref_fn = jax.jit(lambda p, s, c=codec_name:
                             decode_stacked_ref(c, (p, s), L))
            out_ref = ref_fn(payload, scales)
            out_ker = ker_fn(payload, scales, L)
            assert np.array_equal(np.asarray(out_ref),
                                  np.asarray(out_ker)), (
                f"{name} K={K} L={L}: fused decode+mean is not "
                f"bit-identical to decode_stacked_ref")
            t_ref = time_callable(ref_fn, payload, scales, policy=policy)
            t_ker = time_callable(ker_fn, payload, scales, L,
                                  policy=policy)
            wire = payload.shape[1] * payload.dtype.itemsize + 4
            for label, t in ((f"{name}_ref", t_ref),
                             (f"{name}_pallas_interp", t_ker)):
                cell = f"{label}_K{K}_L{L}"
                rows.append({"name": cell,
                             "us_per_call": round(t * 1e6, 1),
                             "derived": f"{K * wire} wire bytes in"})
                timings[cell] = t
            # unpack + scale-multiply per decoded element, sequential
            # adds, one 1/K multiply; reads K wire payloads, writes the
            # (L,) f32 mean — never a (K, L) f32 stack
            d_flops = (K * L * _UNPACK_OPS[codec_name] + K * L
                       + (K - 1) * L + L)
            _roofline(counters, f"{name}_pallas_interp_K{K}_L{L}",
                      d_flops, K * wire + 4 * L, t_ker)
    # -- fused top-k select: k argmax+mask sweeps in VMEM vs the
    # lax.top_k oracle; values, indices and threshold all bit-identical
    topk_ref_fn = jax.jit(topk_select_ref)
    for L in wl.quant_lengths:
        k = get_codec("topk")._k(L)
        dv = jnp.asarray(rng.standard_normal(L), jnp.float32)
        v_ref, i_ref, th_ref = topk_ref_fn(dv)
        v_ker, i_ker, th_ker = topk_select(dv, k)
        assert (np.array_equal(np.asarray(v_ref), np.asarray(v_ker))
                and np.array_equal(np.asarray(i_ref), np.asarray(i_ker))
                and float(th_ref) == float(th_ker)), (
            f"topk L={L} k={k}: Pallas select is not bit-identical to "
            f"the lax.top_k oracle")
        t_ref = time_callable(topk_ref_fn, dv, policy=policy)
        t_ker = time_callable(topk_select, dv, k, policy=policy)
        for label, t in (("topk_ref", t_ref),
                         ("topk_pallas_interp", t_ker)):
            cell = f"{label}_L{L}"
            rows.append({"name": cell,
                         "us_per_call": round(t * 1e6, 1),
                         "derived": f"k={k} of {L}"})
            timings[cell] = t
        # |x| pass + k sweeps of (max, select, mask); ships 2 words
        # per kept entry + the threshold
        _roofline(counters, f"topk_pallas_interp_L{L}",
                  L + 3 * k * L, 4 * L + 8 * k + 4, t_ker)
    notes = ["pallas numbers are interpret-mode (CPU emulation) — "
             "correctness benchmark, not TPU speed",
             "quantize+pack, decode+mean and top-k interpret outputs "
             "asserted bit-identical to the codec oracles at every cell",
             "roofline_*_frac counters divide achieved rates by the TPU "
             "v5e peaks (repro.launch.mesh); model_* counters are the "
             "machine-independent work models, exact-gated in CI"]
    return {"params": {"shapes": [list(s) for s in wl.kernel_shapes],
                       "quant_lengths": list(wl.quant_lengths),
                       "K": K, "reps": reps},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    common.emit("kernels", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
