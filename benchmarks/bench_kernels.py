"""Kernel microbenchmark: Pallas SCD (interpret on CPU; compiled on TPU)
vs the pure-jnp oracle. Prints name,us_per_call,derived CSV."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import scd_steps_kernel, scd_steps_ref


def _time(fn, *args, reps=5, **kw) -> float:
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    for (m, n, H) in ((256, 256, 256), (512, 256, 512), (1024, 512, 1024)):
        A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        colsq = jnp.sum(A * A, 0)
        alpha = jnp.zeros(n, jnp.float32)
        w = jnp.asarray(rng.standard_normal(m), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, H), jnp.int32)
        kw = dict(sigma=8.0, lam=1.0, eta=1.0)
        t_ref = _time(scd_steps_ref, A, colsq, alpha, w, idx, **kw)
        t_ker = _time(scd_steps_kernel, A, colsq, alpha, w, idx, **kw)
        flops = 4.0 * m * H  # dot + axpy per step
        rows.append({"name": f"scd_ref_m{m}_H{H}",
                     "us_per_call": round(t_ref * 1e6, 1),
                     "derived": f"{flops / t_ref / 1e9:.2f}GFLOP/s"})
        rows.append({"name": f"scd_pallas_interp_m{m}_H{H}",
                     "us_per_call": round(t_ker * 1e6, 1),
                     "derived": f"{flops / t_ker / 1e9:.2f}GFLOP/s"})
    common.emit("kernels", rows)
    print("# NOTE: pallas numbers are interpret-mode (CPU emulation) — "
          "correctness benchmark, not TPU speed")
    return rows


if __name__ == "__main__":
    main()
