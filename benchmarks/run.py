# One module per paper figure/table. Each prints CSV rows and writes
# results/bench/<name>.csv; this driver runs them all.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_convergence, bench_h_sweep, bench_kernels,
                            bench_overheads, bench_roofline, bench_scaling)
    quick = "--quick" in sys.argv
    stages = [
        ("Fig3/4 overhead decomposition", bench_overheads.main),
        ("Fig6/7 H trade-off sweep", bench_h_sweep.main),
        ("Fig2/5 convergence vs frameworks + MLlib", bench_convergence.main),
        ("kernel microbench", bench_kernels.main),
        ("roofline table (from dry-run artifacts)", bench_roofline.main),
    ]
    if not quick:
        stages.append(("Fig8 scaling vs workers", bench_scaling.main))
    for name, fn in stages:
        print(f"\n==== {name} ====")
        t0 = time.time()
        fn()
        print(f"# ({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
