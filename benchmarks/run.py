# Legacy entry point — the harness moved to `python -m repro.bench.run`.
# This shim maps the old flags onto the new runner so existing muscle
# memory (`python benchmarks/run.py [--quick]`) keeps working.
from __future__ import annotations

import os
import sys

# Invoked by path (`python benchmarks/run.py`), sys.path[0] is this
# directory — anchor the repo root so `benchmarks.*` stays importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def main() -> int:
    from repro.bench.run import main as bench_main
    argv = sys.argv[1:]
    # old default was the full paper-figure run; respect any explicit tier
    tier_flags = {"--smoke", "--quick", "--full", "--tier"}
    if not tier_flags & set(argv):
        argv = argv + ["--full"]
    print("# benchmarks/run.py is a shim; use `python -m repro.bench.run` "
          "(tiers: --smoke/--quick/--full)")
    return bench_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
