"""Paper Fig 6 + Fig 7: time-to-eps vs H per implementation, optimal H
per framework, and the compute fraction at the optimum — plus the
scheme-aware extension: every algorithm x comm scheme x exchange mode
swept with its modelled wire traffic charged as wall-clock through a
measured link calibration (``TimeModel``), so the sweep exposes how the
communication scheme AND the staleness knob move the optimum, not just
the framework overhead.

rounds-to-eps(H) is MEASURED by running the actual algorithm (the
``stale`` sweeps really run the one-round-delayed apply and pay its
convergence tax); the per-round wall time combines the measured solver
time with each framework profile's calibrated overhead and the scheme's
``comm_bytes / bandwidth + latency`` term — minus the
``min(t_comm, t_compute)`` a stale round hides. On a slow-but-hideable
link that overlap pulls the optimal H back down toward the fast-link
optimum (asserted below): staleness buys back communication time, the
paper's §4-§5 regime as a tunable knob.

The straggler regime rides the same machinery: a straggler-tagged
exchange spec shares the measured trajectory (straggling is time-only
under the BSP barrier) while ``TimeModel`` charges E[max over K
workers] x the solver time — asserted below to move the tuned H DOWN,
both as the grid argmin and through ``autotune_H`` on a smooth fit.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import synthetic_link
from repro.core import COMM_SCHEMES, EXCHANGE_MODES, PROFILES
from repro.core.tradeoff import (NoConvergedPointError, TimeModel,
                                 autotune_H, compute_fraction_at, optimal_H,
                                 time_to_eps)

# the straggler what-if: half the workers straggle 16x — the paper's
# worst-case Spark scheduling-delay regime, strong enough that the
# barrier term must visibly move the tuned H
STRAGGLER_SPEC = "persistent/straggler:mix(p=0.5,slow=16)"

IMPLS = ("A_spark", "B_spark_c", "C_pyspark", "D_pyspark_c",
         "B_spark_opt", "D_pyspark_opt", "E_mpi")

# algorithms included in the per-scheme sweep section, by tier (the
# smoke tier runs all three — grids there are tiny)
SCHEME_SWEEP_ALGOS = ("cocoa", "minibatch_scd", "minibatch_sgd")

# the per-scheme section charges comm against the lowest-overhead
# profile, where the traffic term is most visible (paper §5.5: the
# cheaper the framework, the more the wire matters)
SCHEME_PROFILE = "E_mpi"


def _link(notes: list) -> "object":
    """Live link calibration when a real (>=2-way) mesh exists, else a
    deterministic synthetic link so single-device runs stay meaningful."""
    import jax

    from repro.bench.timing import calibrate_link, synthetic_link

    if len(jax.devices()) >= 2:
        link = calibrate_link("persistent")
        if link.bandwidth_Bps != float("inf"):
            notes.append(f"link calibrated live: "
                         f"{link.bandwidth_Bps / 1e9:.3f} GB/s, "
                         f"latency {link.latency_s * 1e6:.1f} us")
            return link
    link = synthetic_link(1e9, 1e-4)  # 1 GB/s, 100 us — a 10GbE-ish wire
    notes.append("single-device host: synthetic 1 GB/s / 100 us link "
                 "stands in for the measured calibration")
    return link


@benchmark("h_sweep", figures="Fig 6-7",
           description="time-to-eps vs H and the per-framework optimum, "
                       "per comm scheme")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    sweep = common.run_sweep(wl)
    notes = []
    if ctx.tier == "smoke":
        notes += common.assert_rounds_in_band(wl, sweep)

    rows = []
    for name in IMPLS:
        p = PROFILES[name]
        for pt in sweep.points:
            rows.append({
                "impl": name,
                "H": pt.H,
                "H_frac_nlocal": round(pt.H / sweep.n_local, 3),
                "rounds_to_eps": pt.rounds_to_eps,
                "t_solver_s": round(pt.t_solver_s, 6),
                "time_to_eps_s": round(time_to_eps(p, pt, sweep.t_ref_s), 4),
            })

    timings, counters = {"t_ref_solver": sweep.t_ref_s}, {}
    opt_rows = []
    for name in IMPLS:
        p = PROFILES[name]
        try:
            h_opt, t_opt = optimal_H(p, sweep)
        except NoConvergedPointError as e:
            # no grid point reached eps for this profile's sweep: emit a
            # skipped row instead of crashing the whole benchmark
            opt_rows.append({"impl": name, "H_opt": "-",
                             "H_opt_frac_nlocal": "-", "time_to_eps_s": "-",
                             "compute_fraction_at_opt": "-"})
            notes.append(f"{name}: optimum skipped — {e}")
            continue
        opt_rows.append({
            "impl": name,
            "H_opt": h_opt,
            "H_opt_frac_nlocal": round(h_opt / sweep.n_local, 3),
            "time_to_eps_s": round(t_opt, 4),
            "compute_fraction_at_opt": round(
                compute_fraction_at(p, sweep, h_opt), 3),
        })
        timings[f"time_to_eps_{name}"] = t_opt
        counters[f"H_opt_{name}"] = h_opt
    for pt in sweep.points:
        counters[f"rounds_to_eps_H{pt.H}"] = pt.rounds_to_eps

    by = {r["impl"]: r for r in opt_rows if r["H_opt"] != "-"}
    if "D_pyspark_c" in by and "E_mpi" in by:
        shift = by["D_pyspark_c"]["H_opt"] / max(by["E_mpi"]["H_opt"], 1)
        notes.append(f"optimal-H shift pySpark+C vs MPI = {shift:.0f}x "
                     f"(paper: >25x between implementations)")
        notes.append(f"compute fraction at optimum: MPI "
                     f"{by['E_mpi']['compute_fraction_at_opt']:.2f} "
                     f"(paper ~0.9), pySpark+C "
                     f"{by['D_pyspark_c']['compute_fraction_at_opt']:.2f}"
                     f" (paper ~0.6)")
        # mis-tuning cost (paper: using (E)'s H on (D) 'more than doubles')
        pt_mpiH = next(p_ for p_ in sweep.points
                       if p_.H == by["E_mpi"]["H_opt"])
        t_mis = time_to_eps(PROFILES["D_pyspark_c"], pt_mpiH, sweep.t_ref_s)
        notes.append(f"(D) at MPI's H*: {t_mis:.2f}s vs own optimum "
                     f"{by['D_pyspark_c']['time_to_eps_s']}s "
                     f"({t_mis / by['D_pyspark_c']['time_to_eps_s']:.2f}x "
                     f"worse)")

    # ------------------------------------------------------------------
    # per-scheme x per-mode sweeps: every algorithm under every comm
    # scheme and exchange mode, wire traffic charged as seconds through
    # the link calibration (stale rounds hide min(t_comm, t_compute))
    # ------------------------------------------------------------------
    link = _link(notes)
    profile = PROFILES[SCHEME_PROFILE]
    scheme_rows = []
    for algo in SCHEME_SWEEP_ALGOS:
        ranking = {}  # scheme -> (bytes, t_round at the reference H)
        ref_t = None  # ONE measured (t_solver, t_ref) for the ranking:
        # `compressed` re-measures its own (noisier, genuinely slower)
        # solver round, and letting that noise into the fixed-H ranking
        # would decide the order by jitter instead of by the wire term
        for mode in EXCHANGE_MODES:
            for scheme in COMM_SCHEMES:
                ssweep = common.run_sweep(wl, algorithm=algo, scheme=scheme,
                                          mode=mode)
                model = TimeModel(profile, link=link).for_sweep(ssweep)
                cell = (f"{algo}_{scheme}"
                        + ("" if mode == "sync" else f"_{mode}"))
                counters[f"comm_bytes_per_round_{cell}"] = \
                    ssweep.comm_bytes_per_round
                if mode == "sync":
                    if ref_t is None:
                        # largest-H point of the first scheme's sweep
                        ref_t = (ssweep.points[-1].t_solver_s,
                                 ssweep.t_ref_s)
                    ranking[scheme] = (ssweep.comm_bytes_per_round,
                                       model.round_time(*ref_t))
                try:
                    h_opt, t_opt = optimal_H(model, ssweep)
                except NoConvergedPointError as e:
                    scheme_rows.append({"algorithm": algo, "scheme": scheme,
                                        "mode": mode,
                                        "H_opt": "-", "time_to_eps_s": "-",
                                        "comm_bytes_per_round":
                                            ssweep.comm_bytes_per_round})
                    notes.append(f"{cell}: optimum skipped — {e}")
                    continue
                # wire seconds as the model charged them AT the
                # optimum: under stale that is the overhang left after
                # hiding behind H_opt's measured compute, so the row's
                # comm_s and time_to_eps share one set of assumptions
                pt_opt = next(p for p in ssweep.points if p.H == h_opt)
                comm_s = model.comm_time_s(
                    profile.compute_mult * pt_opt.t_solver_s)
                scheme_rows.append({
                    "algorithm": algo, "scheme": scheme, "mode": mode,
                    "H_opt": h_opt,
                    "time_to_eps_s": round(t_opt, 4),
                    "comm_bytes_per_round": ssweep.comm_bytes_per_round,
                    "comm_s_per_round": round(comm_s, 6),
                })
                timings[f"time_to_eps_{cell}"] = t_opt
                counters[f"H_opt_{cell}"] = h_opt
        # the time model must rank schemes exactly as their modelled
        # traffic does at a fixed H (same measured compute, same link;
        # sync only — under stale, fully-hidden schemes tie at zero
        # wire cost and the order within the tie is meaningless)
        by_bytes = sorted(ranking, key=lambda s: ranking[s][0])
        by_time = sorted(ranking, key=lambda s: ranking[s][1])
        assert by_bytes == by_time, (
            f"{algo}: scheme ranking by modelled traffic {by_bytes} != "
            f"ranking by modelled round time {by_time}")
        notes.append(f"{algo}: scheme order at fixed H (cheapest first) "
                     f"= {by_bytes} — time model tracks modelled traffic")
        notes += _assert_stale_shifts_H_down(algo, wl, profile)
        notes += _assert_straggler_shifts_H_down(algo, wl, counters)

    return {"params": {"m": wl.m, "n": wl.n, "K": wl.K,
                       "h_grid": common.h_grid(wl), "eps": wl.eps,
                       "schemes": list(COMM_SCHEMES),
                       "modes": list(EXCHANGE_MODES),
                       "scheme_profile": SCHEME_PROFILE},
            "timings_s": timings, "counters": counters,
            "rows": rows + opt_rows + scheme_rows, "notes": notes}


def _assert_stale_shifts_H_down(algo: str, wl, profile) -> list[str]:
    """The paper's qualitative staleness result, pinned: on a slow link
    whose transfer time is hideable behind local compute, the stale
    mode's overlap term moves the optimal H DOWN (toward the fast-link
    optimum) and never costs time-to-eps.

    The what-if link is sized so t_comm equals the compute term at the
    smallest grid H: at every grid point the stale round fully hides the
    wire, so its cost curve is the no-comm curve, while the sync curve
    pays the constant wire term per round — which (for decreasing
    rounds-to-eps) can only push the sync argmin up. Both optima use the
    SAME measured sync sweep, so the comparison isolates the overlap
    term and stays deterministic up to solver-time monotonicity in H."""
    ssweep = common.run_sweep(wl, algorithm=algo, scheme="persistent")
    if any(p.rounds_to_eps is None for p in ssweep.points):
        return [f"{algo}: stale H*-shift check skipped (unconverged grid "
                f"point in the persistent sweep)"]
    pt0 = min(ssweep.points, key=lambda p: p.H)
    t_hide = max(profile.compute_mult * pt0.t_solver_s, 1e-9)
    slow = synthetic_link(max(ssweep.comm_bytes_per_round, 1) / t_hide)
    h_sync, t_sync = optimal_H(
        TimeModel(profile, ssweep.comm_bytes_per_round, slow), ssweep)
    h_stale, t_stale = optimal_H(
        TimeModel(profile, ssweep.comm_bytes_per_round, slow,
                  exchange="stale"), ssweep)
    assert h_stale <= h_sync, (
        f"{algo}: stale mode moved H* UP on a hideable slow link "
        f"({h_stale} > {h_sync})")
    assert t_stale <= t_sync + 1e-12, (
        f"{algo}: stale mode cost time-to-eps on a hideable slow link "
        f"({t_stale} > {t_sync})")
    return [f"{algo}: hideable slow link H* sync={h_sync} -> "
            f"stale={h_stale} (time-to-eps {t_sync:.4f}s -> "
            f"{t_stale:.4f}s) — staleness buys back communication time"]


def _assert_straggler_shifts_H_down(algo: str, wl, counters) -> list[str]:
    """The new straggler regime's qualitative prediction, pinned: the
    barrier charges E[max over K workers] x the solver time, so a strong
    straggler profile inflates the compute term while the per-round
    framework overhead stays fixed — the overhead is *relatively*
    cheaper, and the tuned H must move DOWN (or stay), never up.

    Checked two ways on the SAME measured persistent sweep (the
    straggler-tagged sweep shares its trajectory — straggling is
    time-only): the grid argmin via :func:`optimal_H`, and
    :func:`autotune_H` over a smooth power-law fit of the measured
    rounds/solver-time curves (golden-section needs a continuous model;
    three grid points would pin the search to its own probes)."""
    base_sweep = common.run_sweep(wl, algorithm=algo, scheme="persistent")
    if any(p.rounds_to_eps is None for p in base_sweep.points):
        return [f"{algo}: straggler H*-shift check skipped (unconverged "
                f"grid point in the persistent sweep)"]
    strag_sweep = common.run_sweep(wl, algorithm=algo,
                                   scheme=STRAGGLER_SPEC)
    # overhead-heavy profile + modest link: the barrier/overhead trade
    # is what moves H*, so make the overhead term the one that matters
    profile = PROFILES["D_pyspark_c"]
    link = synthetic_link(1e9, 1e-4)
    base = TimeModel(profile, link=link).for_sweep(base_sweep)
    strag = TimeModel(profile, link=link).for_sweep(strag_sweep)
    mult = strag.barrier_mult
    h_sync, _ = optimal_H(base, base_sweep)
    h_strag, _ = optimal_H(strag, strag_sweep)
    assert h_strag <= h_sync, (
        f"{algo}: straggler barrier moved grid H* UP ({h_strag} > "
        f"{h_sync}) under {STRAGGLER_SPEC} (barrier x{mult:.2f})")
    hs = np.array([p.H for p in base_sweep.points], float)
    rs = np.array([p.rounds_to_eps for p in base_sweep.points], float)
    ts = np.array([p.t_solver_s for p in base_sweep.points], float)
    b_r, a_r = np.polyfit(np.log(hs), np.log(np.maximum(rs, 1.0)), 1)
    b_t, a_t = np.polyfit(np.log(hs), np.log(np.maximum(ts, 1e-9)), 1)

    def rounds_fn(H):
        return float(np.exp(a_r) * H ** b_r)

    def tsolve_fn(H):
        return float(np.exp(a_t) * H ** b_t)

    lo, hi = int(hs.min()), int(hs.max())
    h_auto = autotune_H(rounds_fn, lambda H: base.round_time(
        tsolve_fn(H), base_sweep.t_ref_s), lo, hi)
    h_auto_strag = autotune_H(rounds_fn, lambda H: strag.round_time(
        tsolve_fn(H), base_sweep.t_ref_s), lo, hi)
    assert h_auto_strag <= h_auto, (
        f"{algo}: straggler barrier moved autotuned H* UP "
        f"({h_auto_strag} > {h_auto}) under {STRAGGLER_SPEC}")
    counters[f"H_opt_{algo}_straggler_grid"] = h_strag
    counters[f"H_opt_{algo}_straggler_autotuned"] = h_auto_strag
    return [f"{algo}: straggler barrier x{mult:.2f} shifts H* "
            f"grid {h_sync} -> {h_strag}, autotuned {h_auto} -> "
            f"{h_auto_strag} — overhead is relatively cheaper when the "
            f"barrier stretches compute"]


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    sweep_rows = [r for r in out["rows"] if "H" in r]
    opt_rows = [r for r in out["rows"] if "H_opt" in r and "scheme" not in r]
    scheme_rows = [r for r in out["rows"] if "scheme" in r]
    common.emit("fig6_time_vs_H", sweep_rows)
    common.emit("fig7_optimal_H", opt_rows)
    common.emit("fig6_schemes", scheme_rows)
    for note in out["notes"]:
        print(f"# {note}")
    return opt_rows


if __name__ == "__main__":
    main()
