"""Paper Fig 6 + Fig 7: time-to-eps vs H per implementation, optimal H
per framework, and the compute fraction at the optimum.

rounds-to-eps(H) is MEASURED by running the actual algorithm; the
per-round wall time combines the measured solver time with each
framework profile's calibrated overhead.
"""
from __future__ import annotations

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.core import PROFILES
from repro.core.tradeoff import compute_fraction_at, optimal_H, time_to_eps

IMPLS = ("A_spark", "B_spark_c", "C_pyspark", "D_pyspark_c",
         "B_spark_opt", "D_pyspark_opt", "E_mpi")


@benchmark("h_sweep", figures="Fig 6-7",
           description="time-to-eps vs H and the per-framework optimum")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    sweep = common.run_sweep(wl)
    notes = []
    if ctx.tier == "smoke":
        notes += common.assert_rounds_in_band(wl, sweep)

    rows = []
    for name in IMPLS:
        p = PROFILES[name]
        for pt in sweep.points:
            rows.append({
                "impl": name,
                "H": pt.H,
                "H_frac_nlocal": round(pt.H / sweep.n_local, 3),
                "rounds_to_eps": pt.rounds_to_eps,
                "t_solver_s": round(pt.t_solver_s, 6),
                "time_to_eps_s": round(time_to_eps(p, pt, sweep.t_ref_s), 4),
            })

    timings, counters = {"t_ref_solver": sweep.t_ref_s}, {}
    opt_rows = []
    for name in IMPLS:
        p = PROFILES[name]
        h_opt, t_opt = optimal_H(p, sweep)
        opt_rows.append({
            "impl": name,
            "H_opt": h_opt,
            "H_opt_frac_nlocal": round(h_opt / sweep.n_local, 3),
            "time_to_eps_s": round(t_opt, 4),
            "compute_fraction_at_opt": round(
                compute_fraction_at(p, sweep, h_opt), 3),
        })
        timings[f"time_to_eps_{name}"] = t_opt
        counters[f"H_opt_{name}"] = h_opt
    for pt in sweep.points:
        counters[f"rounds_to_eps_H{pt.H}"] = pt.rounds_to_eps

    by = {r["impl"]: r for r in opt_rows}
    shift = by["D_pyspark_c"]["H_opt"] / max(by["E_mpi"]["H_opt"], 1)
    notes.append(f"optimal-H shift pySpark+C vs MPI = {shift:.0f}x "
                 f"(paper: >25x between implementations)")
    notes.append(f"compute fraction at optimum: MPI "
                 f"{by['E_mpi']['compute_fraction_at_opt']:.2f} (paper ~0.9), "
                 f"pySpark+C {by['D_pyspark_c']['compute_fraction_at_opt']:.2f}"
                 f" (paper ~0.6)")
    # mis-tuning cost (paper: using (E)'s H on (D) 'more than doubles')
    pt_mpiH = next(p_ for p_ in sweep.points
                   if p_.H == by["E_mpi"]["H_opt"])
    t_mis = time_to_eps(PROFILES["D_pyspark_c"], pt_mpiH, sweep.t_ref_s)
    notes.append(f"(D) at MPI's H*: {t_mis:.2f}s vs own optimum "
                 f"{by['D_pyspark_c']['time_to_eps_s']}s "
                 f"({t_mis / by['D_pyspark_c']['time_to_eps_s']:.2f}x worse)")
    return {"params": {"m": wl.m, "n": wl.n, "K": wl.K,
                       "h_grid": common.h_grid(wl), "eps": wl.eps},
            "timings_s": timings, "counters": counters,
            "rows": rows + opt_rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    sweep_rows = [r for r in out["rows"] if "H" in r]
    opt_rows = [r for r in out["rows"] if "H_opt" in r]
    common.emit("fig6_time_vs_H", sweep_rows)
    common.emit("fig7_optimal_H", opt_rows)
    for note in out["notes"]:
        print(f"# {note}")
    return opt_rows


if __name__ == "__main__":
    main()
