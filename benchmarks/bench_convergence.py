"""Paper Fig 2 + Fig 5: suboptimality-over-time per implementation and
the comparison against the MLlib-style SGD baseline.

Each implementation runs at its OWN optimal H (as the paper does);
wall-clock = measured rounds x (measured solver time x compute_mult +
calibrated overhead).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import PROFILES
from repro.core.baselines import MinibatchSGD, SGDConfig
from repro.core.tradeoff import optimal_H, time_to_eps

IMPLS = ("A_spark", "B_spark_c", "C_pyspark", "D_pyspark_c",
         "B_spark_opt", "D_pyspark_opt", "E_mpi")


def main() -> list[dict]:
    sweep = common.run_sweep()
    rows = []
    for name in IMPLS:
        p = PROFILES[name]
        h_opt, t_opt = optimal_H(p, sweep)
        rows.append({"impl": name, "H_opt": h_opt,
                     "time_to_eps_s": round(t_opt, 3)})
    by = {r["impl"]: r for r in rows}
    t_mpi = by["E_mpi"]["time_to_eps_s"]
    for r in rows:
        r["gap_vs_mpi"] = round(r["time_to_eps_s"] / t_mpi, 2)

    # MLlib-style SGD baseline (Fig 5), tuned batch fraction
    A, b, _ = common.problem()
    tr = common.trainer(64)
    best_sgd = np.inf
    for bf, lr in ((0.1, 3e-4), (0.5, 3e-4), (1.0, 1e-3), (1.0, 3e-3)):
        sgd = MinibatchSGD(SGDConfig(batch_frac=bf, step_size=lr,
                                     lam=common.LAM, K=common.K), A, b)
        hist = sgd.run(4000, p_star=tr.p_star, p_zero=tr.p_zero,
                       record_every=25, target_eps=common.EPS)
        r2e = hist.rounds_to(common.EPS)
        if r2e is not None:
            # charge SGD the pySpark profile (it's the MLlib solver) with
            # its n-dim gradient communication per round
            p = PROFILES["C_pyspark"]
            t = r2e * p.round_time(0.005, sweep.t_ref_s)
            best_sgd = min(best_sgd, t)
    rows.append({"impl": "MLlib_SGD(pyspark)",
                 "H_opt": "-",
                 "time_to_eps_s": (round(best_sgd, 1)
                                   if np.isfinite(best_sgd) else "inf"),
                 "gap_vs_mpi": (round(best_sgd / t_mpi, 1)
                                if np.isfinite(best_sgd) else "inf")})
    common.emit("fig2_fig5_convergence", rows)
    print(f"# paper headline: (A) vs MPI ~10x -> ours "
          f"{by['A_spark']['gap_vs_mpi']}x; optimized (B)*/(D)* < 2x -> "
          f"ours {by['B_spark_opt']['gap_vs_mpi']}x / "
          f"{by['D_pyspark_opt']['gap_vs_mpi']}x")
    return rows


if __name__ == "__main__":
    main()
