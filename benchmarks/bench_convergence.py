"""Paper Fig 2 + Fig 5: suboptimality-over-time per implementation and
the comparison against the MLlib-style SGD baseline.

Each implementation runs at its OWN optimal H (as the paper does);
wall-clock = measured rounds x (measured solver time x compute_mult +
calibrated overhead).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import measure_solver_time
from repro.core import PROFILES
from repro.core.baselines import MinibatchSGD, SGDConfig
from repro.core.tradeoff import NoConvergedPointError, optimal_H, time_to_eps

IMPLS = ("A_spark", "B_spark_c", "C_pyspark", "D_pyspark_c",
         "B_spark_opt", "D_pyspark_opt", "E_mpi")

# (batch_frac, step_size) grid for the tuned MLlib-style SGD baseline.
SGD_GRID = ((0.1, 3e-4), (0.5, 3e-4), (1.0, 1e-3), (1.0, 3e-3))


@benchmark("convergence", figures="Fig 2+5",
           description="time-to-eps per implementation vs MLlib-style SGD")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    sweep = common.run_sweep(wl)
    rows, timings, counters, notes = [], {}, {}, []
    t_opts = {}
    for name in IMPLS:
        p = PROFILES[name]
        try:
            h_opt, t_opt = optimal_H(p, sweep)
        except NoConvergedPointError as e:
            rows.append({"impl": name, "H_opt": "-", "time_to_eps_s": "-"})
            notes.append(f"{name}: optimum skipped — {e}")
            continue
        t_opts[name] = t_opt
        rows.append({"impl": name, "H_opt": h_opt,
                     "time_to_eps_s": round(t_opt, 4)})
        timings[f"time_to_eps_{name}"] = t_opt
    by = {r["impl"]: r for r in rows}
    # ratios from the raw optima — the rounded display values can
    # quantize to 0.0 at smoke-tier microsecond scales
    t_mpi = t_opts.get("E_mpi", float("nan"))
    for r in rows:
        if r["impl"] not in t_opts or "E_mpi" not in t_opts:
            r["gap_vs_mpi"] = "-"
            continue
        r["gap_vs_mpi"] = round(t_opts[r["impl"]] / t_mpi, 2)
        counters[f"gap_vs_mpi_{r['impl']}"] = r["gap_vs_mpi"]

    # MLlib-style SGD baseline (Fig 5), tuned over a small grid; the smoke
    # tier runs one setting to keep the gate in seconds.
    A, b, _ = common.problem(wl)
    tr = common.trainer(wl, 64)
    grid = SGD_GRID[-1:] if ctx.tier == "smoke" else SGD_GRID
    best_sgd = np.inf
    for bf, lr in grid:
        sgd = MinibatchSGD(SGDConfig(batch_frac=bf, step_size=lr,
                                     lam=wl.lam, K=wl.K), A, b)
        hist = sgd.run(wl.sgd_rounds, p_star=tr.p_star, p_zero=tr.p_zero,
                       record_every=25, target_eps=wl.eps)
        r2e = hist.rounds_to(wl.eps)
        if r2e is not None:
            # charge SGD the pySpark profile (it's the MLlib solver) with
            # ITS OWN measured per-round gradient time (the serial K
            # virtual workers are divided by K like every sweep point) —
            # a hardcoded 5 ms stand-in overcharged fast tiers and
            # undercharged slow ones identically for every batch_frac
            t_sgd = measure_solver_time(sgd, sgd.cfg.H,
                                        reps=wl.reps) / wl.K
            p = PROFILES["C_pyspark"]
            t = r2e * p.round_time(t_sgd, sweep.t_ref_s)
            best_sgd = min(best_sgd, t)
    rows.append({"impl": "MLlib_SGD(pyspark)",
                 "H_opt": "-",
                 "time_to_eps_s": (round(best_sgd, 2)
                                   if np.isfinite(best_sgd) else "inf"),
                 "gap_vs_mpi": (round(best_sgd / t_mpi, 1)
                                if np.isfinite(best_sgd) and
                                np.isfinite(t_mpi) else "inf")})
    if np.isfinite(best_sgd):
        timings["time_to_eps_MLlib_SGD"] = float(best_sgd)
    notes.append(
        f"paper headline: (A) vs MPI ~10x -> ours "
        f"{by['A_spark']['gap_vs_mpi']}x; optimized (B)*/(D)* < 2x -> "
        f"ours {by['B_spark_opt']['gap_vs_mpi']}x / "
        f"{by['D_pyspark_opt']['gap_vs_mpi']}x")
    return {"params": {"m": wl.m, "n": wl.n, "K": wl.K, "eps": wl.eps,
                       "sgd_rounds": wl.sgd_rounds},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    common.emit("fig2_fig5_convergence", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
