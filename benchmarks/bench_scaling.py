"""Paper Fig 8: time-to-eps vs number of workers K, H re-optimized per
point, per framework profile + the zero-overhead ideal."""
from __future__ import annotations

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.core import PROFILES
from repro.core.tradeoff import NoConvergedPointError, optimal_H

IMPLS = ("B_spark_c", "D_pyspark_opt", "E_mpi")


@benchmark("scaling", figures="Fig 8",
           description="time-to-eps vs worker count, H re-optimized")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    rows, timings, counters, notes = [], {}, {}, []
    for K_ in wl.scaling_ks:
        sweep = common.run_sweep(wl, K_=K_)
        # zero-overhead ideal (the paper's dashed line): compute only
        ideal = min((pt.rounds_to_eps * pt.t_solver_s
                     for pt in sweep.points if pt.rounds_to_eps), default=None)
        for name in IMPLS:
            try:
                h_opt, t_opt = optimal_H(PROFILES[name], sweep)
            except NoConvergedPointError as e:
                notes.append(f"K={K_} {name}: optimum skipped — {e}")
                continue
            rows.append({"K": K_, "impl": name, "H_opt": h_opt,
                         "time_to_eps_s": round(t_opt, 4)})
            timings[f"time_to_eps_K{K_}_{name}"] = t_opt
            counters[f"H_opt_K{K_}_{name}"] = h_opt
        if ideal is not None:
            rows.append({"K": K_, "impl": "ideal_no_comm", "H_opt": "-",
                         "time_to_eps_s": round(ideal, 4)})
            timings[f"time_to_eps_K{K_}_ideal"] = ideal
    for name in IMPLS + ("ideal_no_comm",):
        ts = [r["time_to_eps_s"] for r in rows if r["impl"] == name]
        if not ts:
            notes.append(f"{name}: no K reached eps in {wl.max_rounds} rounds")
            continue
        notes.append(f"{name}: K={wl.scaling_ks[0]} -> {ts[0]}s, "
                     f"K={wl.scaling_ks[-1]} -> {ts[-1]}s "
                     f"(speedup {ts[0] / ts[-1]:.2f}x)")
        counters[f"speedup_{name}"] = round(ts[0] / ts[-1], 3)
    return {"params": {"m": wl.m, "n": wl.n, "Ks": list(wl.scaling_ks),
                       "eps": wl.eps},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    common.emit("fig8_scaling", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
