"""Paper Fig 8: time-to-eps vs number of workers K, H re-optimized per
point, per framework profile + the zero-overhead ideal."""
from __future__ import annotations

from benchmarks import common
from repro.core import PROFILES
from repro.core.tradeoff import optimal_H

KS = (2, 4, 8, 16)
IMPLS = ("B_spark_c", "D_pyspark_opt", "E_mpi")


def main() -> list[dict]:
    rows = []
    for K_ in KS:
        sweep = common.run_sweep(K_=K_)
        # zero-overhead ideal (the paper's dashed line): compute only
        ideal = min((pt.rounds_to_eps * pt.t_solver_s
                     for pt in sweep.points if pt.rounds_to_eps), default=None)
        for name in IMPLS:
            h_opt, t_opt = optimal_H(PROFILES[name], sweep)
            rows.append({"K": K_, "impl": name, "H_opt": h_opt,
                         "time_to_eps_s": round(t_opt, 3)})
        rows.append({"K": K_, "impl": "ideal_no_comm", "H_opt": "-",
                     "time_to_eps_s": round(ideal, 3)})
    common.emit("fig8_scaling", rows)
    # scaling verdict per impl
    for name in IMPLS + ("ideal_no_comm",):
        ts = [r["time_to_eps_s"] for r in rows if r["impl"] == name]
        print(f"# {name}: K=2 -> {ts[0]}s, K={KS[-1]} -> {ts[-1]}s "
              f"(speedup {ts[0] / ts[-1]:.2f}x)")
    return rows


if __name__ == "__main__":
    main()
