"""Driver/transport/codec/exchange-mode coverage: the full 3-algorithm
x (transport x codec) x 2-mode matrix (paper §4-§5.4) on the unified
distributed-driver layer.

Every algorithm (CoCoA, mini-batch SCD, mini-batch SGD) runs under every
communication scheme — the exact transports `persistent`,
`spark_faithful`, `reduce_scatter` plus the codec-composed `compressed`
transport with each wire codec (`compressed:f32` identity,
`compressed:int8`, packed `compressed:int4`) — and every exchange mode
(`sync`, `stale` — the one-round-delayed apply, the paper's Spark
scheduling-delay regime as a knob) through BOTH execution drivers — the
vmap virtual-worker path and the shard_map path — with fixed seeds and
rounds-to-eps asserted within per-algorithm tolerance bands in the
smoke tier (the CI gate).

For each of the 36 (algorithm x scheme x mode) cells the modelled
`comm_bytes_per_round` is checked against the optimized HLO of the
sharded round: for master-centric schemes the derived per-round traffic
is 2 x K x per-worker collective operand bytes (excluding the scalar
metric psum) — under `compressed` that operand is the codec's wire
tuple (int8 payload + f32 scale; for int4 a packed ceil(m/2)-byte u8
payload + f32 scale); for `reduce_scatter` it is the ring volume —
(K-1) x the reduce-scatter operand plus K x (K-1) x the all-gather
operand, i.e. 2*(K-1)/K of the padded vector per worker each way.
Derived must equal the model exactly — in BOTH modes: the stale
exchange delays the apply but still runs the identical collective every
round, so staleness may never change the bytes on the wire. The HLO is
also checked for the codec's wire dtype (s8 / packed u8 all-gathers
present exactly when the codec is int8 / int4).

On top of the matrix, REGIME_CELLS exercise the full ExchangeConfig
grammar: a straggler profile (asserted trajectory-identical to the base
cell — straggling is charged by TimeModel's barrier, never by the
drivers), bounded staleness `stale:k=2`, and elastic membership
(`drop:w@d-r`), whose live-round `comm_bytes_per_round(t)` must be
exactly K_live/K of the full-membership traffic while the compiled
collective — and hence the HLO bytes — is unchanged. BACKEND_CELLS
extend the matrix along the collective-backend axis: each transport on
the explicit `ring` fabric, where the derived traffic is K x the HLO's
collective-permute operand bytes and the codec's wire dtype must ride
every hop.

CODEC_CELLS extend along the codec axis: the packed `int2` and sparse
`topk(r=..)` base codecs (coarse-eps cells, like int4 — they pin wire
bytes and early progress per byte) and the stateful `ef:` error-
feedback wrapper, which runs at the BASE eps: the bench's headline
asserts that `compressed:ef:int4` reaches the same rounds-to-eps band
as f32 on the smoke problem while plain `compressed:int4` provably
floors (~6e-2) and never gets there in the whole round budget. The ef:
cells also compose with `stale:k`, `drop:`, and the ring backend, so
the drivers' codec-state threading is exercised under every regime
that could corrupt it.

`run_sharded` needs a
multi-device mesh — `python -m repro.bench.run --smoke` fakes one via
``--xla_force_host_platform_device_count``; when only one device exists
(e.g. in-process tests) the sharded leg degrades to a K=1 mesh, which
still exercises the collective code paths but skips the byte checks
(XLA elides single-participant collectives).
"""
from __future__ import annotations

import re
import time

from benchmarks import common
# the cell matrix and byte derivation are owned by repro.analysis —
# the bench re-asserts what `python -m repro.analysis` lints, on the
# SAME cells and through the SAME graph API (no local HLO walking)
from repro.analysis.cells import (ALGORITHMS, BACKEND_CELLS, CODEC_CELLS,
                                  MODES, REGIME_CELLS, SCHEMES)
from repro.analysis.traffic import codec_wire_dtype
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import time_callable
from repro.core.distributed import CommScheme, ExchangeConfig
from repro.core.glm import suboptimality

# Fixed-seed rounds-to-eps bands per algorithm (smoke tier: m=96, n=256,
# K=4, seed 42 data / seed 0 trainer). Measured centers ~15 / ~32 / ~93;
# bands leave ~3x headroom for jax-version jitter. The int8 codec
# tolerates 2x extra rounds from quantization error, and `stale` gets
# 1.5x band headroom for the one-round-delayed apply — measured cost on
# the smoke problem is within +-2 rounds of sync (the metric honestly
# lags one round, and CoCoA's conservative sigma=K damping absorbs —
# here slightly over-relaxes through — the staleness), but the tax
# grows with conditioning so the band stays loose.
SMOKE_BANDS = {
    "cocoa": (2, 60),
    "minibatch_scd": (8, 120),
    "minibatch_sgd": (25, 300),
}
STALE_BAND_MULT = 1.5


# Per-codec eps multipliers, calibrated to each codec's quantization
# noise floor on the smoke problem. int8: mini-batch SCD's 1/sigma-
# damped updates shrink per-round progress relative to the quantizer's
# absmax scale, so its noise floor sits near 2e-3; CoCoA and SGD
# converge through it. int4's grid is ~17x coarser (scale absmax/7.5 vs
# absmax/127), so its floor sits near 6e-2 (9e-2 for damped SCD) — the
# int4 cells therefore run at a coarse eps ~2x above that floor: the
# honest trade of the 8x-cheaper wire is early progress per byte, not
# tight tolerance. Coarse eps is hit in a handful of rounds, so the
# int4 cells drop the per-algorithm lower band (lo=1).
# int2's ternary grid and plain topk's dropped tail floor far higher
# still (~0.36-0.41 normalized subopt on the smoke problem): their
# cells run at eps = 512 x 1e-3 ~= 1.3-1.4x the floor — they exist to
# pin wire bytes and early progress, not tolerance. The ef:-wrapped
# codecs deliberately have NO entry: error feedback is claimed to reach
# the BASE eps (the f32 band), and the bench asserts exactly that.
CODEC_EPS_MULT = {
    "int8": {"cocoa": 1, "minibatch_scd": 4, "minibatch_sgd": 1},
    "int4": {"cocoa": 128, "minibatch_scd": 192, "minibatch_sgd": 16},
    "int2": {"cocoa": 512},
    "topk(r=0.125)": {"cocoa": 512},
}

def _eps(algo: str, scheme: str, wl) -> float:
    # the sqrt-decay SGD schedule cannot hit 1e-3 in smoke budgets;
    # 10x looser still separates the schemes
    eps = 10 * wl.eps if algo == "minibatch_sgd" else wl.eps
    codec = CommScheme.parse(scheme).codec.name
    return eps * CODEC_EPS_MULT.get(codec, {}).get(algo, 1)


def _band(algo: str, scheme: str, mode: str) -> tuple[int, int]:
    lo, hi = SMOKE_BANDS[algo]
    codec = CommScheme.parse(scheme).codec.name
    if codec == "int8":
        hi *= 2          # quantization error costs extra rounds
    elif codec in ("int4", "int2") or codec.startswith("topk"):
        lo, hi = 1, hi   # coarse eps (see CODEC_EPS_MULT) is hit fast
    # ef:<base> keeps the unmodified per-algorithm band: error feedback
    # must land the lossy codec in the SAME rounds-to-eps band as f32
    if mode == "stale":
        hi = int(STALE_BAND_MULT * hi)
    return lo, hi


def _make_trainer(algo: str, wl, tier: str, K: int, scheme: str, mode: str,
                  seed: int):
    from repro.core import (CoCoAConfig, CoCoATrainer, MinibatchSCD,
                            MinibatchSGD, SGDConfig)

    A, b, _ = common.problem(wl)
    ex = common._exchange_of(scheme, mode)
    if algo == "minibatch_sgd":
        # the tier-calibrated MLlib-style base step lives on the workload
        return MinibatchSGD(
            SGDConfig(batch_frac=1.0, step_size=wl.sgd_step,
                      lam=wl.lam, K=K, seed=seed, exchange=ex), A, b)
    cfg = CoCoAConfig(K=K, H=common.n_local(wl, K), lam=wl.lam,
                      solver="scd_ref", exchange=ex, seed=seed)
    cls = MinibatchSCD if algo == "minibatch_scd" else CoCoATrainer
    return cls(cfg, A, b)


def _run_virtual(tr, wl, eps):
    """(rounds_to_eps, per-round seconds, final subopt) for the
    vmap virtual-worker driver."""
    import jax

    from repro.core import MinibatchSGD

    if isinstance(tr, MinibatchSGD):
        hist = tr.run_workers(wl.max_rounds, record_every=1, target_eps=eps)
    else:
        hist = tr.run(wl.max_rounds, record_every=1, target_eps=eps)
    t = time_callable(tr._round_fn, *tr.init_state(), jax.random.key(0))
    return hist.rounds_to(eps), t, hist.subopt[-1]


def _run_sharded(tr, wl, eps, round_fn):
    """Same, driving the shard_map round manually so compile time stays
    out of the per-round measurement (first round discarded)."""
    import jax

    from repro.core import distributed as dist

    mesh = round_fn.mesh

    def init():
        return dist.place_state(mesh, *tr.init_state())

    # warmup on throwaway state so compile time never lands in a timed
    # round (the measured run may converge in a single round)
    local, shared = init()
    jax.block_until_ready(round_fn(local, shared, jax.random.key(999), 1)[2])
    local, shared = init()
    key = jax.random.key(tr.cfg.seed)
    times, rounds_to_eps, subopt = [], None, float("inf")
    for t in range(1, wl.max_rounds + 1):
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        local, shared, primal = round_fn(local, shared, sub, t)
        subopt = suboptimality(float(primal), tr.p_star, tr.p_zero)
        times.append(time.perf_counter() - t0)
        if subopt <= eps:
            rounds_to_eps = t
            break
    return rounds_to_eps, min(times), subopt


def _hlo_traffic(tr, round_fn):
    """(derived bytes/round, quantized wire dtypes present) from the
    optimized HLO of the sharded round — via the repro.analysis graph
    API, the single owner of the byte derivation (master-centric
    2 x K x operand, reduce-scatter ring volume, ring K x ppermute; see
    repro.analysis.traffic.derived_round_traffic)."""
    from repro.analysis.cells import lower_round_hlo
    from repro.analysis.graph import lift_hlo
    from repro.analysis.traffic import (derived_round_traffic,
                                        quantized_wire_dtypes)

    graph = lift_hlo(lower_round_hlo(tr, round_fn))
    return (derived_round_traffic(graph, tr.exchange, tr.cfg.K),
            quantized_wire_dtypes(graph))


@benchmark("drivers", figures="§5.3-5.4",
           description="3 algorithms x (transport x codec) x 2 exchange "
                       "modes, virtual + sharded")
def run(ctx: BenchContext) -> dict:
    import jax

    from repro.utils.compat import make_mesh

    wl = common.workload(ctx.tier)
    K_sh = min(wl.K, len(jax.devices()))
    mesh = make_mesh((K_sh,), ("workers",))
    rows, timings, counters, notes = [], {}, {}, []
    base_traj = {}   # algo -> (virtual r2e, final subopt) at persistent/sync
    for algo in ALGORITHMS:
        for scheme in SCHEMES:
            # ':' would leak into counter keys and shell-unfriendly
            # row labels; cells use the flattened form
            scheme_key = scheme.replace(":", "_")
            codec = CommScheme.parse(scheme).codec.name
            for mode in MODES:
                eps = _eps(algo, scheme, wl)
                lo, band_hi = _band(algo, scheme, mode)
                mode_sfx = "" if mode == "sync" else f"_{mode}"
                tr_v = _make_trainer(algo, wl, ctx.tier, wl.K, scheme, mode,
                                     ctx.seed)
                r_v, t_v, s_v = _run_virtual(tr_v, wl, eps)
                if scheme == "persistent" and mode == "sync":
                    base_traj[algo] = (r_v, s_v)
                tr_s = _make_trainer(algo, wl, ctx.tier, K_sh, scheme, mode,
                                     ctx.seed)
                round_fn = tr_s.build_sharded_round(mesh)  # 1 compile/cell
                r_s, t_s, s_s = _run_sharded(tr_s, wl, eps, round_fn)
                modelled = tr_s.comm_bytes_per_round()
                derived, wire_dt = (_hlo_traffic(tr_s, round_fn)
                                    if K_sh >= 2 else (None, None))
                for driver, r2e, t_round, sub in (
                        ("virtual", r_v, t_v, s_v),
                        ("sharded", r_s, t_s, s_s)):
                    cell = f"{algo}_{driver}_{scheme_key}{mode_sfx}"
                    rows.append({"algorithm": algo, "driver": driver,
                                 "scheme": scheme, "codec": codec,
                                 "mode": mode,
                                 "rounds_to_eps": r2e,
                                 "t_round_s": round(t_round, 6),
                                 "final_subopt": f"{sub:.2e}",
                                 "comm_bytes_per_round": modelled,
                                 "hlo_bytes_per_round": derived})
                    timings[f"{cell}_round"] = t_round
                    counters[f"rounds_to_eps_{cell}"] = (
                        r2e if r2e is not None else -1)
                    # bands are calibrated at K = wl.K; a device-starved
                    # sharded leg (K_sh < wl.K) converges differently
                    if ctx.tier == "smoke" and (driver == "virtual"
                                                or K_sh == wl.K):
                        assert r2e is not None, (
                            f"{cell} did not reach eps={eps} in "
                            f"{wl.max_rounds} rounds (final subopt "
                            f"{sub:.2e})")
                        assert lo <= r2e <= band_hi, (
                            f"{cell} rounds_to_eps={r2e} outside the "
                            f"calibrated band [{lo}, {band_hi}]")
                # the modelled bytes depend on the sharded worker count,
                # so a device-starved run (K_sh < wl.K) must not emit
                # counters that would pair with — and exactly mismatch —
                # a full-mesh baseline under `compare --exact-counter`
                suffix = "" if K_sh == wl.K else f"_K{K_sh}"
                counters[f"comm_bytes_per_round_{algo}_{scheme_key}"
                         f"{mode_sfx}{suffix}"] = modelled
                if derived is not None:
                    counters[f"hlo_bytes_per_round_{algo}_{scheme_key}"
                             f"{mode_sfx}{suffix}"] = derived
                    assert modelled == derived, (
                        f"{algo}/{scheme}/{mode}: modelled "
                        f"comm_bytes_per_round {modelled} != {derived} "
                        f"derived from the HLO collectives (K={K_sh})")
                    expect_dt = codec_wire_dtype(codec)
                    expect = {expect_dt} if expect_dt else set()
                    assert wire_dt == expect, (
                        f"{algo}/{scheme}/{mode}: quantized collective "
                        f"dtypes {wire_dt} do not match the codec "
                        f"(expected {expect})")
                notes.append(f"{algo}/{scheme}/{mode}: virtual {r_v}, "
                             f"sharded (K={K_sh}) {r_s} rounds to "
                             f"eps={eps}; {modelled} modelled bytes/round"
                             + (f" == {derived} from HLO"
                                if derived is not None else ""))
    # --- regime cells: straggler / staleness / elastic / backend / codec
    for algo, spec in REGIME_CELLS + BACKEND_CELLS + CODEC_CELLS:
        ex = ExchangeConfig.parse(spec)
        cell_key = re.sub(r"[^a-z0-9]+", "_", spec.lower()).strip("_")
        eps = _eps(algo, ex.scheme.name, wl)
        lo, band_hi = _band(algo, ex.scheme.name, ex.mode.name)
        codec = ex.scheme.codec.name
        tr_v = _make_trainer(algo, wl, ctx.tier, wl.K, spec, "sync",
                             ctx.seed)
        r_v, t_v, s_v = _run_virtual(tr_v, wl, eps)
        if ex.straggler.active and ex.membership.empty and not ex.mode.stale:
            # straggling is charged by TimeModel's barrier, never by the
            # drivers: the trajectory must be bit-identical to base
            r_b, s_b = base_traj[algo]
            assert r_v == r_b and s_v == s_b, (
                f"{spec}: straggler profile changed the trajectory "
                f"({r_v} rounds/subopt {s_v:.2e} vs base {r_b}/{s_b:.2e})"
                " — stragglers must be time-only")
        if (ex.backend != "xla" and ex.scheme.name == "persistent"
                and not ex.mode.stale and not ex.straggler.active
                and ex.membership.empty):
            # the virtual driver sums stacked per-worker updates with no
            # collectives at all — a backend segment may never change it
            r_b, s_b = base_traj[algo]
            assert r_v == r_b and s_v == s_b, (
                f"{spec}: collective backend changed the VIRTUAL "
                f"trajectory ({r_v} rounds/subopt {s_v:.2e} vs base "
                f"{r_b}/{s_b:.2e}) — the vmap driver is backend-"
                f"oblivious by construction")
        # membership events name absolute worker indices; a
        # device-starved mesh (K_sh < wl.K) cannot host them
        run_sh = ex.membership.empty or K_sh == wl.K
        if run_sh:
            tr_s = _make_trainer(algo, wl, ctx.tier, K_sh, spec, "sync",
                                 ctx.seed)
            round_fn = tr_s.build_sharded_round(mesh)
            r_s, t_s, s_s = _run_sharded(tr_s, wl, eps, round_fn)
            modelled = tr_s.comm_bytes_per_round()
            derived, wire_dt = (_hlo_traffic(tr_s, round_fn)
                                if K_sh >= 2 else (None, None))
        else:
            tr_s = tr_v
            r_s = t_s = s_s = None
            modelled, derived, wire_dt = tr_v.comm_bytes_per_round(), None, \
                None
        legs = [("virtual", r_v, t_v, s_v)]
        if run_sh:
            legs.append(("sharded", r_s, t_s, s_s))
        for driver, r2e, t_round, sub in legs:
            cell = f"{algo}_{driver}_{cell_key}"
            rows.append({"algorithm": algo, "driver": driver,
                         "scheme": spec, "codec": codec,
                         "mode": ex.mode.spec,
                         "rounds_to_eps": r2e,
                         "t_round_s": round(t_round, 6),
                         "final_subopt": f"{sub:.2e}",
                         "comm_bytes_per_round": modelled,
                         "hlo_bytes_per_round": derived})
            timings[f"{cell}_round"] = t_round
            counters[f"rounds_to_eps_{cell}"] = (
                r2e if r2e is not None else -1)
            if ctx.tier == "smoke" and (driver == "virtual"
                                        or K_sh == wl.K):
                assert r2e is not None, (
                    f"{cell} did not reach eps={eps} in "
                    f"{wl.max_rounds} rounds (final subopt {sub:.2e})")
                assert lo <= r2e <= band_hi, (
                    f"{cell} rounds_to_eps={r2e} outside the "
                    f"calibrated band [{lo}, {band_hi}]")
        # keyed by algorithm too: CODEC_CELLS reuse one spec across
        # algorithms, and their modelled bytes differ (SGD moves an
        # n-vector where the CoCoA family moves m)
        suffix = "" if K_sh == wl.K or not run_sh else f"_K{K_sh}"
        counters[f"comm_bytes_per_round_{algo}_{cell_key}{suffix}"] = modelled
        if derived is not None:
            counters[f"hlo_bytes_per_round_{algo}_{cell_key}{suffix}"] = derived
            assert modelled == derived, (
                f"{spec}: modelled comm_bytes_per_round {modelled} != "
                f"{derived} derived from the HLO collectives (K={K_sh})"
                " — membership masking must stay outside the collective")
            expect_dt = codec_wire_dtype(codec)
            expect = {expect_dt} if expect_dt else set()
            assert wire_dt == expect, (
                f"{spec}: quantized collective dtypes {wire_dt} do not "
                f"match the codec (expected {expect})")
        if not ex.membership.empty:
            # live-round traffic scales with the live-worker count while
            # the compiled collective (and its HLO bytes) is unchanged
            w, d, _ = ex.membership.events[0]
            K_model = tr_s.cfg.K
            live = tr_s.comm_bytes_per_round(t=d)
            k_live = ex.membership.live_count(d, K_model)
            assert live * K_model == modelled * k_live, (
                f"{spec}: live-round bytes {live} at t={d} should be "
                f"{k_live}/{K_model} of the full-membership {modelled}")
            counters[f"comm_bytes_per_round_{algo}_{cell_key}_live"
                     f"{suffix}"] = live
            notes.append(f"{spec}: round t={d} moves {live} bytes "
                         f"({k_live}/{K_model} live) vs {modelled} full")
        notes.append(f"{algo}/{spec}: virtual {r_v}, sharded "
                     f"(K={K_sh}) {r_s} rounds to eps={eps}; "
                     f"{modelled} modelled bytes/round"
                     + (f" == {derived} from HLO"
                        if derived is not None else ""))
    # --- headline: error feedback lifts the int4 convergence floor ----
    # Plain compressed:int4 cells above run at a coarse eps because the
    # biased grid floors near 6e-2 on the smoke problem; ef:int4 runs at
    # the BASE eps and was just asserted inside the f32 rounds band. Pin
    # both halves of that claim explicitly: the floor is real (plain
    # int4 never reaches tight eps in the whole budget) and error
    # feedback removes it (ef:int4 reaches it in the f32 band).
    if ctx.tier == "smoke":
        tr_plain = _make_trainer("cocoa", wl, ctx.tier, wl.K,
                                 "compressed:int4", "sync", ctx.seed)
        h_plain = tr_plain.run(wl.max_rounds, record_every=1,
                               target_eps=wl.eps)
        r_ef = counters["rounds_to_eps_cocoa_virtual_compressed_ef_int4"]
        lo, hi = SMOKE_BANDS["cocoa"]
        assert h_plain.rounds_to(wl.eps) is None and             h_plain.subopt[-1] > 10 * wl.eps, (
                f"plain compressed:int4 reached eps={wl.eps} "
                f"(final subopt {h_plain.subopt[-1]:.2e}) — the int4 "
                f"floor this bench documents has moved; recalibrate "
                f"CODEC_EPS_MULT and the ef: headline")
        assert lo <= r_ef <= hi, (
            f"ef:int4 rounds_to_eps={r_ef} is outside the f32 band "
            f"[{lo}, {hi}] — error feedback no longer lifts the int4 "
            f"floor to baseline convergence")
        notes.append(
            f"headline: cocoa compressed:int4 floors at subopt "
            f"{h_plain.subopt[-1]:.2e} after {wl.max_rounds} rounds "
            f"(never reaches eps={wl.eps}); compressed:ef:int4 reaches "
            f"it in {r_ef} rounds — inside the f32 band [{lo}, {hi}]")
    if K_sh < wl.K:
        notes.append(f"only {K_sh} device(s) — run via `python -m "
                     f"repro.bench.run --smoke` to fake {wl.K} CPU devices"
                     + ("; HLO byte checks skipped" if K_sh < 2 else ""))
    return {"params": {"m": wl.m, "n": wl.n, "K_virtual": wl.K,
                       "K_sharded": K_sh, "eps": wl.eps,
                       "algorithms": list(ALGORITHMS),
                       "schemes": list(SCHEMES),
                       "modes": list(MODES),
                       "regime_cells": [list(c) for c in REGIME_CELLS],
                       "backend_cells": [list(c) for c in BACKEND_CELLS],
                       "codec_cells": [list(c) for c in CODEC_CELLS]},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="quick"))
    common.emit("drivers", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
