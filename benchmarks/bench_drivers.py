"""Driver/comm-scheme/exchange-mode coverage: the full 3-algorithm x
4-scheme x 2-mode matrix (paper §4-§5.4) on the unified
distributed-driver layer.

Every algorithm (CoCoA, mini-batch SCD, mini-batch SGD) runs under every
communication scheme (`persistent`, `spark_faithful`, `compressed`,
`reduce_scatter`) and every exchange mode (`sync`, `stale` — the
one-round-delayed apply, the paper's Spark scheduling-delay regime as a
knob) through BOTH execution drivers — the vmap virtual-worker path and
the shard_map path — with fixed seeds and rounds-to-eps asserted within
per-algorithm tolerance bands in the smoke tier (the CI gate).

For each of the 24 (algorithm x scheme x mode) cells the modelled
`comm_bytes_per_round` is checked against the optimized HLO of the
sharded round: for master-centric schemes the derived per-round traffic
is 2 x K x per-worker collective operand bytes (excluding the scalar
metric psum); for `reduce_scatter` it is the ring volume — (K-1) x the
reduce-scatter operand plus K x (K-1) x the all-gather operand, i.e.
2*(K-1)/K of the padded vector per worker each way. Derived must equal
the model exactly — in BOTH modes: the stale exchange delays the apply
but still runs the identical collective every round, so staleness may
never change the bytes on the wire. `run_sharded` needs a multi-device
mesh — `python -m repro.bench.run --smoke` fakes one via
``--xla_force_host_platform_device_count``; when only one device exists
(e.g. in-process tests) the sharded leg degrades to a K=1 mesh, which
still exercises the collective code paths but skips the byte checks
(XLA elides single-participant collectives).
"""
from __future__ import annotations

import re
import time

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import time_callable
from repro.core.distributed import COMM_SCHEMES, EXCHANGE_MODES
from repro.core.glm import suboptimality

SCHEMES = COMM_SCHEMES
MODES = EXCHANGE_MODES
ALGORITHMS = ("cocoa", "minibatch_scd", "minibatch_sgd")

# Fixed-seed rounds-to-eps bands per algorithm (smoke tier: m=96, n=256,
# K=4, seed 42 data / seed 0 trainer). Measured centers ~15 / ~32 / ~93;
# bands leave ~3x headroom for jax-version jitter. The `compressed`
# scheme tolerates 2x extra rounds from int8 quantization error, and
# `stale` gets 1.5x band headroom for the one-round-delayed apply —
# measured cost on the smoke problem is within +-2 rounds of sync (the
# metric honestly lags one round, and CoCoA's conservative sigma=K
# damping absorbs — here slightly over-relaxes through — the staleness),
# but the tax grows with conditioning so the band stays loose.
SMOKE_BANDS = {
    "cocoa": (2, 60),
    "minibatch_scd": (8, 120),
    "minibatch_sgd": (25, 300),
}
STALE_BAND_MULT = 1.5


# mini-batch SCD's 1/sigma-damped updates shrink per-round progress
# relative to the quantizer's absmax scale, so its int8 noise floor sits
# near 2e-3 on the smoke problem; CoCoA and SGD converge through it
COMPRESSED_EPS_MULT = {"cocoa": 1, "minibatch_scd": 4, "minibatch_sgd": 1}


def _eps(algo: str, scheme: str, wl) -> float:
    # the sqrt-decay SGD schedule cannot hit 1e-3 in smoke budgets;
    # 10x looser still separates the schemes
    eps = 10 * wl.eps if algo == "minibatch_sgd" else wl.eps
    if scheme == "compressed":
        eps *= COMPRESSED_EPS_MULT[algo]
    return eps


def _make_trainer(algo: str, wl, tier: str, K: int, scheme: str, mode: str,
                  seed: int):
    from repro.core import (CoCoAConfig, CoCoATrainer, MinibatchSCD,
                            MinibatchSGD, SGDConfig)

    A, b, _ = common.problem(wl)
    if algo == "minibatch_sgd":
        # the tier-calibrated MLlib-style base step lives on the workload
        return MinibatchSGD(
            SGDConfig(batch_frac=1.0, step_size=wl.sgd_step,
                      lam=wl.lam, K=K, seed=seed, comm_scheme=scheme,
                      exchange_mode=mode), A, b)
    cfg = CoCoAConfig(K=K, H=common.n_local(wl, K), lam=wl.lam,
                      solver="scd_ref", comm_scheme=scheme,
                      exchange_mode=mode, seed=seed)
    cls = MinibatchSCD if algo == "minibatch_scd" else CoCoATrainer
    return cls(cfg, A, b)


def _run_virtual(tr, wl, eps):
    """(rounds_to_eps, per-round seconds, final subopt) for the
    vmap virtual-worker driver."""
    import jax

    from repro.core import MinibatchSGD

    if isinstance(tr, MinibatchSGD):
        hist = tr.run_workers(wl.max_rounds, record_every=1, target_eps=eps)
    else:
        hist = tr.run(wl.max_rounds, record_every=1, target_eps=eps)
    t = time_callable(tr._round_fn, *tr.init_state(), jax.random.key(0))
    return hist.rounds_to(eps), t, hist.subopt[-1]


def _run_sharded(tr, wl, eps, round_fn):
    """Same, driving the shard_map round manually so compile time stays
    out of the per-round measurement (first round discarded)."""
    import jax

    from repro.core import distributed as dist

    mesh = round_fn.mesh

    def init():
        return dist.place_state(mesh, *tr.init_state())

    # warmup on throwaway state so compile time never lands in a timed
    # round (the measured run may converge in a single round)
    local, shared = init()
    jax.block_until_ready(round_fn(local, shared, jax.random.key(999), 1)[2])
    local, shared = init()
    key = jax.random.key(tr.cfg.seed)
    times, rounds_to_eps, subopt = [], None, float("inf")
    for t in range(1, wl.max_rounds + 1):
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        local, shared, primal = round_fn(local, shared, sub, t)
        subopt = suboptimality(float(primal), tr.p_star, tr.p_zero)
        times.append(time.perf_counter() - t0)
        if subopt <= eps:
            rounds_to_eps = t
            break
    return rounds_to_eps, min(times), subopt


def _hlo_traffic(tr, round_fn):
    """(derived bytes/round, int8 collective present) from the optimized
    HLO of the sharded round.

    Master-centric schemes: derived = 2 x K x per-worker collective
    operand bytes; the one scalar f32 metric psum (4 bytes) is excluded
    — everything else is update/state traffic through the master.
    ``reduce_scatter``: the ring volume — each worker moves (K-1)/K of
    the reduce-scatter operand and (K-1) x its all-gather shard, so
    derived = (K-1) x rs_operand + K x (K-1) x ag_operand (the metric
    psum shows up as an all-reduce and is simply not counted)."""
    import jax

    from repro.utils.hlo import parse_collectives

    local, shared = tr.init_state()
    txt = round_fn.jitted.lower(round_fn.split_keys(jax.random.key(0)),
                                local, shared, 1).compile().as_text()
    stats = parse_collectives(txt)
    K = tr.cfg.K
    if tr.scheme.name == "reduce_scatter":
        _, rs_ob, _ = stats.by_kind.get("reduce-scatter", (0, 0, 0))
        _, ag_ob, _ = stats.by_kind.get("all-gather", (0, 0, 0))
        derived = (K - 1) * rs_ob + K * (K - 1) * ag_ob
    else:
        derived = 2 * K * (stats.total_operand_bytes - 4)
    int8 = bool(re.search(r"s8\[[0-9,]+\]\S* all-gather", txt))
    return derived, int8


@benchmark("drivers", figures="§5.3-5.4",
           description="3 algorithms x 4 comm schemes x 2 exchange modes, "
                       "virtual + sharded")
def run(ctx: BenchContext) -> dict:
    import jax

    from repro.utils.compat import make_mesh

    wl = common.workload(ctx.tier)
    K_sh = min(wl.K, len(jax.devices()))
    mesh = make_mesh((K_sh,), ("workers",))
    rows, timings, counters, notes = [], {}, {}, []
    for algo in ALGORITHMS:
        lo, hi = SMOKE_BANDS[algo]
        for scheme in SCHEMES:
            for mode in MODES:
                eps = _eps(algo, scheme, wl)
                # compressed tolerates extra rounds from int8
                # quantization, stale from the one-round-delayed apply
                band_hi = 2 * hi if scheme == "compressed" else hi
                if mode == "stale":
                    band_hi = int(STALE_BAND_MULT * band_hi)
                mode_sfx = "" if mode == "sync" else f"_{mode}"
                tr_v = _make_trainer(algo, wl, ctx.tier, wl.K, scheme, mode,
                                     ctx.seed)
                r_v, t_v, s_v = _run_virtual(tr_v, wl, eps)
                tr_s = _make_trainer(algo, wl, ctx.tier, K_sh, scheme, mode,
                                     ctx.seed)
                round_fn = tr_s.build_sharded_round(mesh)  # 1 compile/cell
                r_s, t_s, s_s = _run_sharded(tr_s, wl, eps, round_fn)
                modelled = tr_s.comm_bytes_per_round()
                derived, int8 = (_hlo_traffic(tr_s, round_fn) if K_sh >= 2
                                 else (None, None))
                for driver, r2e, t_round, sub in (
                        ("virtual", r_v, t_v, s_v),
                        ("sharded", r_s, t_s, s_s)):
                    cell = f"{algo}_{driver}_{scheme}{mode_sfx}"
                    rows.append({"algorithm": algo, "driver": driver,
                                 "scheme": scheme, "mode": mode,
                                 "rounds_to_eps": r2e,
                                 "t_round_s": round(t_round, 6),
                                 "final_subopt": f"{sub:.2e}",
                                 "comm_bytes_per_round": modelled,
                                 "hlo_bytes_per_round": derived})
                    timings[f"{cell}_round"] = t_round
                    counters[f"rounds_to_eps_{cell}"] = (
                        r2e if r2e is not None else -1)
                    # bands are calibrated at K = wl.K; a device-starved
                    # sharded leg (K_sh < wl.K) converges differently
                    if ctx.tier == "smoke" and (driver == "virtual"
                                                or K_sh == wl.K):
                        assert r2e is not None, (
                            f"{cell} did not reach eps={eps} in "
                            f"{wl.max_rounds} rounds (final subopt "
                            f"{sub:.2e})")
                        assert lo <= r2e <= band_hi, (
                            f"{cell} rounds_to_eps={r2e} outside the "
                            f"calibrated band [{lo}, {band_hi}]")
                # the modelled bytes depend on the sharded worker count,
                # so a device-starved run (K_sh < wl.K) must not emit
                # counters that would pair with — and exactly mismatch —
                # a full-mesh baseline under `compare --exact-counter`
                suffix = "" if K_sh == wl.K else f"_K{K_sh}"
                counters[f"comm_bytes_per_round_{algo}_{scheme}"
                         f"{mode_sfx}{suffix}"] = modelled
                if derived is not None:
                    counters[f"hlo_bytes_per_round_{algo}_{scheme}"
                             f"{mode_sfx}{suffix}"] = derived
                    assert modelled == derived, (
                        f"{algo}/{scheme}/{mode}: modelled "
                        f"comm_bytes_per_round {modelled} != {derived} "
                        f"derived from the HLO collectives (K={K_sh})")
                    assert int8 == (scheme == "compressed"), (
                        f"{algo}/{scheme}/{mode}: int8 collective "
                        f"presence {int8} does not match the scheme")
                notes.append(f"{algo}/{scheme}/{mode}: virtual {r_v}, "
                             f"sharded (K={K_sh}) {r_s} rounds to "
                             f"eps={eps}; {modelled} modelled bytes/round"
                             + (f" == {derived} from HLO"
                                if derived is not None else ""))
    if K_sh < wl.K:
        notes.append(f"only {K_sh} device(s) — run via `python -m "
                     f"repro.bench.run --smoke` to fake {wl.K} CPU devices"
                     + ("; HLO byte checks skipped" if K_sh < 2 else ""))
    return {"params": {"m": wl.m, "n": wl.n, "K_virtual": wl.K,
                       "K_sharded": K_sh, "eps": wl.eps,
                       "algorithms": list(ALGORITHMS),
                       "schemes": list(SCHEMES),
                       "modes": list(MODES)},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="quick"))
    common.emit("drivers", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
