"""Driver/comm-scheme coverage: both CoCoA execution drivers (the vmap
virtual-worker `run` and the shard_map `run_sharded`) under all three
communication schemes (`persistent`, `spark_faithful`, `compressed`).

The smoke tier is the CI gate: fixed seeds, tiny problem, and
rounds-to-eps asserted within tolerance bands for every driver x scheme.
`run_sharded` needs a multi-device mesh — `python -m repro.bench.run
--smoke` fakes one via ``--xla_force_host_platform_device_count``; when
only one device exists (e.g. in-process tests) the sharded leg degrades
to a K=1 mesh, which still exercises the collective code paths.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import time_callable
from repro.core.glm import suboptimality

SCHEMES = ("persistent", "spark_faithful", "compressed")


def _run_virtual(tr, wl):
    """(rounds_to_eps, per-round seconds, final subopt) for `run`."""
    hist = tr.run(wl.max_rounds, record_every=1, target_eps=wl.eps)
    import jax
    alpha, w = tr.init_state()
    t = time_callable(tr._round_fn, alpha, w, jax.random.key(0))
    return hist.rounds_to(wl.eps), t, hist.subopt[-1]


def _run_sharded(tr, wl):
    """Same, driving `build_sharded_round` manually so compile time stays
    out of the per-round measurement (first round discarded)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.utils.compat import make_mesh

    mesh = make_mesh((tr.cfg.K,), ("workers",))
    round_fn = tr.build_sharded_round(mesh)

    def init():
        alpha, w = tr.init_state()
        alpha = jax.device_put(alpha, NamedSharding(mesh, P("workers")))
        w = jax.device_put(w, NamedSharding(mesh, P(None)))
        return alpha, w

    # warmup on throwaway state so compile time never lands in a timed
    # round (the measured run may converge in a single round)
    alpha, w = init()
    jax.block_until_ready(
        round_fn(alpha, w, jax.random.key_data(jax.random.key(999)))[2])
    alpha, w = init()
    key = jax.random.key(tr.cfg.seed)
    times, rounds_to_eps, subopt = [], None, float("inf")
    for t in range(wl.max_rounds):
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        alpha, w, primal = round_fn(alpha, w, jax.random.key_data(sub))
        subopt = suboptimality(float(primal), tr.p_star, tr.p_zero)
        times.append(time.perf_counter() - t0)
        if subopt <= wl.eps:
            rounds_to_eps = t + 1
            break
    return rounds_to_eps, min(times), subopt


@benchmark("drivers", figures="§5.3",
           description="run vs run_sharded under all three comm schemes")
def run(ctx: BenchContext) -> dict:
    import jax

    wl = common.workload(ctx.tier)
    nl = common.n_local(wl)
    K_sh = min(wl.K, len(jax.devices()))
    rows, timings, counters, notes = [], {}, {}, []
    lo, hi = wl.rounds_band
    for scheme in SCHEMES:
        # compressed tolerates extra rounds from int8 quantization error
        band_hi = 2 * hi if scheme == "compressed" else hi
        tr_v = common.trainer(wl, nl, solver="scd_ref", comm_scheme=scheme,
                              seed=ctx.seed)
        r_v, t_v, s_v = _run_virtual(tr_v, wl)
        tr_s = common.trainer(wl, common.n_local(wl, K_sh), solver="scd_ref",
                              comm_scheme=scheme, K_=K_sh, seed=ctx.seed)
        r_s, t_s, s_s = _run_sharded(tr_s, wl)
        for driver, r2e, t_round, sub in (("virtual", r_v, t_v, s_v),
                                          ("sharded", r_s, t_s, s_s)):
            rows.append({"driver": driver, "scheme": scheme,
                         "rounds_to_eps": r2e,
                         "t_round_s": round(t_round, 6),
                         "final_subopt": f"{sub:.2e}"})
            timings[f"{driver}_{scheme}_round"] = t_round
            counters[f"rounds_to_eps_{driver}_{scheme}"] = (
                r2e if r2e is not None else -1)
            if ctx.tier == "smoke":
                assert r2e is not None, (
                    f"{driver}/{scheme} did not reach eps={wl.eps} "
                    f"in {wl.max_rounds} rounds (final subopt {sub:.2e})")
                assert lo <= r2e <= band_hi, (
                    f"{driver}/{scheme} rounds_to_eps={r2e} outside the "
                    f"calibrated band [{lo}, {band_hi}]")
        notes.append(f"{scheme}: virtual {r_v} rounds, sharded (K={K_sh}) "
                     f"{r_s} rounds to eps={wl.eps}")
    if K_sh < wl.K:
        notes.append(f"only {K_sh} device(s) — run via `python -m "
                     f"repro.bench.run --smoke` to fake {wl.K} CPU devices")
    return {"params": {"m": wl.m, "n": wl.n, "K_virtual": wl.K,
                       "K_sharded": K_sh, "H": nl, "eps": wl.eps,
                       "schemes": list(SCHEMES)},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    out = run(BenchContext(tier="quick"))
    common.emit("drivers", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
