"""Shared, tier-aware fixtures for the paper-figure benchmarks.

The workload mirrors the paper's webspam ridge regression at CPU-feasible
scale (see DESIGN.md §2): K workers, eps target, H in fractions of
n_local, overhead profiles (A)-(E) calibrated to Fig 3. Three tiers:

  * ``smoke`` — seconds, fixed seeds, tiny m/n/H grid; the CI gate.
  * ``quick`` — minutes; the dev loop.
  * ``full``  — the paper-figure setting (the old hard-coded constants).

Problems and H-sweeps are cached per (tier, K, solver) so benchmarks that
share a sweep (h_sweep, convergence, scaling) pay for it once per run.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass

import numpy as np

from repro.core import (CoCoAConfig, CoCoATrainer, CommScheme,
                        ExchangeConfig, MinibatchSGD, SGDConfig,
                        StragglerProfile)
from repro.core.tradeoff import (HSweep, HSweepPoint, make_trainer,
                                 measure_solver_time)
from repro.data import make_glm_data

RESULTS_DIR = os.environ.get("BENCH_OUT", "results/bench")

# schemes whose exchange is an exact f32 sum: identical trajectories
# (the virtual driver sums all of them the same way), so a measured
# sweep can be shared between them and only the byte accounting differs
# — including the compressed transport under the f32 identity codec;
# quantizing codecs (compressed[:int8], compressed:int4) really re-run
EXACT_SUM_SCHEMES = ("persistent", "spark_faithful", "reduce_scatter",
                     "compressed:f32")


@dataclass(frozen=True)
class Workload:
    """One tier's problem sizes and measurement effort."""
    m: int
    n: int
    K: int
    density: float
    eps: float
    lam: float
    h_fracs: tuple          # x n_local — the paper's Fig-6 axis
    max_rounds: int
    decomp_rounds: int      # rounds in the Fig-3 decomposition
    sgd_rounds: int         # MLlib-SGD baseline budget (Fig 5)
    scaling_ks: tuple       # worker counts for Fig 8
    kernel_shapes: tuple    # (m, n, H) triples for the microbench
    quant_lengths: tuple    # update-vector lengths for the fused
    #                         quantize+pack kernel microbench
    reps: int               # timing repetitions
    sgd_step: float         # MLlib-style base step size for the tier
    sgd_h_grid: tuple       # local-SGD H grid (local steps per round)
    seed: int = 42
    # smoke-tier tolerance band on measured rounds-to-eps at H = n_local
    # (deterministic given the fixed seeds; band is ~3x around measured)
    rounds_band: tuple = (1, 10_000)


WORKLOADS: dict[str, Workload] = {
    "smoke": Workload(
        m=96, n=256, K=4, density=0.2, eps=1e-3, lam=1.0,
        h_fracs=(0.2, 1.0, 4.0), max_rounds=400,
        decomp_rounds=10, sgd_rounds=400, scaling_ks=(2, 4),
        kernel_shapes=((64, 64, 64), (128, 64, 128), (512, 64, 384)),
        quant_lengths=(96, 1024),
        reps=1, sgd_step=0.1, sgd_h_grid=(1, 4), rounds_band=(2, 180)),
    "quick": Workload(
        m=256, n=1024, K=8, density=0.15, eps=1e-3, lam=1.0,
        h_fracs=(0.05, 0.2, 1.0, 4.0), max_rounds=1000,
        decomp_rounds=50, sgd_rounds=2000, scaling_ks=(2, 4, 8),
        kernel_shapes=((256, 256, 256), (512, 256, 512)),
        quant_lengths=(1024, 16384),
        reps=2, sgd_step=0.05, sgd_h_grid=(1, 4, 16)),
    "full": Workload(
        m=512, n=2048, K=8, density=0.15, eps=1e-3, lam=1.0,
        h_fracs=(0.05, 0.2, 1.0, 4.0, 16.0), max_rounds=1500,
        decomp_rounds=100, sgd_rounds=4000, scaling_ks=(2, 4, 8, 16),
        kernel_shapes=((256, 256, 256), (512, 256, 512), (1024, 512, 1024)),
        quant_lengths=(1024, 16384, 262144),
        reps=2, sgd_step=0.05, sgd_h_grid=(1, 4, 16)),
}

# Back-compat aliases (the old module-level constants = the full tier).
_FULL = WORKLOADS["full"]
EPS, K, M, N, LAM, H_FRACS = (_FULL.eps, _FULL.K, _FULL.m, _FULL.n,
                              _FULL.lam, _FULL.h_fracs)


def workload(tier: str = "full") -> Workload:
    if tier not in WORKLOADS:
        raise KeyError(f"unknown tier {tier!r}; known: {list(WORKLOADS)}")
    return WORKLOADS[tier]


def emit(name: str, rows: list[dict]) -> None:
    """Legacy CSV emitter (the standalone `python benchmarks/bench_X.py`
    path); the harness writes BENCH_<name>.json instead."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    keys = list(rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(str(r[k]) for k in keys))
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# -> {path}")
    for line in lines:
        print(line)


_PROBLEMS: dict = {}
_SWEEPS: dict = {}


def problem(wl: Workload):
    key = (wl.m, wl.n, wl.density, wl.seed)
    if key not in _PROBLEMS:
        _PROBLEMS[key] = make_glm_data(m=wl.m, n=wl.n, density=wl.density,
                                       zipf_a=1.1, seed=wl.seed)
    return _PROBLEMS[key]


def n_local(wl: Workload, K_: int | None = None) -> int:
    return int(np.ceil(wl.n / (K_ or wl.K)))


def h_grid(wl: Workload, K_: int | None = None) -> list[int]:
    nl = n_local(wl, K_)
    return [max(1, int(f * nl)) for f in wl.h_fracs]


def _exchange_of(scheme: str, mode: str) -> ExchangeConfig:
    """Fold the legacy (scheme, mode) pair of knobs into one
    :class:`ExchangeConfig`; ``scheme`` may itself be a full exchange
    spec (``"persistent/straggler:det(slow=4)"``), ``mode`` any mode
    spelling (``"sync"`` / ``"stale"`` / ``"stale:k=2"``)."""
    return ExchangeConfig.parse(scheme if mode == "sync"
                                else f"{scheme}/{mode}")


def trainer(wl: Workload, H: int, solver: str = "scd_kernel",
            K_: int | None = None, seed: int = 0,
            comm_scheme: str = "persistent") -> CoCoATrainer:
    A, b, _ = problem(wl)
    return CoCoATrainer(
        CoCoAConfig(K=K_ or wl.K, H=H, lam=wl.lam, eta=1.0, solver=solver,
                    exchange=comm_scheme, seed=seed),
        A, b)


def bench_trainer(wl: Workload, algorithm: str, H: int,
                  solver: str = "scd_kernel", K_: int | None = None,
                  seed: int = 0, scheme: str = "persistent",
                  mode: str = "sync",
                  exchange: ExchangeConfig | str | None = None):
    """Any of the three driver-layer algorithms on the tier workload.

    ``exchange`` (a full spec) overrides the legacy (scheme, mode) pair.
    """
    A, b, _ = problem(wl)
    K_ = K_ or wl.K
    ex = (ExchangeConfig.parse(exchange) if exchange is not None
          else _exchange_of(scheme, mode))
    if algorithm == "minibatch_sgd":
        cfg = SGDConfig(batch_frac=1.0, step_size=wl.sgd_step, lam=wl.lam,
                        K=K_, H=H, seed=seed, exchange=ex)
    else:
        cfg = CoCoAConfig(K=K_, H=H, lam=wl.lam, eta=1.0, solver=solver,
                          exchange=ex, seed=seed)
    return make_trainer(algorithm, cfg, A, b)


def sweep_eps(wl: Workload, algorithm: str) -> float:
    """The sqrt-decay SGD schedule cannot hit the CoCoA-family eps in
    tier budgets; 10x looser still separates schemes and frameworks."""
    return 10 * wl.eps if algorithm == "minibatch_sgd" else wl.eps


def run_sweep(wl: Workload, K_: int | None = None,
              solver: str = "scd_kernel", algorithm: str = "cocoa",
              scheme: str = "persistent", mode: str = "sync") -> HSweep:
    """Measured rounds-to-eps + solver wall time per H (paper Fig 6 raw)
    for any algorithm x exchange config on the driver layer, cached per
    (tier workload, K, solver, algorithm, canonical exchange spec).
    ``scheme`` may be a full exchange spec; ``mode`` is folded in.

    The K virtual workers execute SERIALLY on this host, so the measured
    per-round solver time is divided by K to model the real cluster where
    workers run concurrently (the paper's setting).

    Two sharing rules keep the grid affordable:

    * Exact-sum schemes (persistent / spark_faithful / reduce_scatter /
      compressed:f32) share one measured trajectory per (mode,
      membership) — the virtual driver reduces all of them with the same
      f32 sum, so only the modelled traffic differs; quantizing codecs
      really are re-run (int8/int4 error changes the trajectory), and so
      is each exchange mode (the delayed apply changes the trajectory
      for every scheme).
    * Straggler profiles never change the trajectory at all (the BSP
      barrier makes straggling a wall-clock effect, not a numeric one),
      so a straggler-tagged spec reuses the straggler-free sweep and
      only re-tags ``HSweep.exchange`` for the time model.
    """
    K_ = K_ or wl.K
    ex = _exchange_of(scheme, mode)
    key = (wl, K_, solver, algorithm, ex.spec)
    if key in _SWEEPS:
        return _SWEEPS[key]
    if ex.straggler.active:
        base_ex = dataclasses.replace(ex, straggler=StragglerProfile())
        base = run_sweep(wl, K_, solver, algorithm, base_ex.spec)
        sweep = HSweep(
            eps=base.eps, n_local=base.n_local, t_ref_s=base.t_ref_s,
            points=list(base.points), algorithm=algorithm,
            scheme=ex.scheme.name, mode=ex.mode.spec,
            comm_bytes_per_round=base.comm_bytes_per_round,
            exchange=ex.spec, workers=K_)
        _SWEEPS[key] = sweep
        return sweep
    if ex.scheme.name in EXACT_SUM_SCHEMES and ex.scheme.name != "persistent":
        base_ex = dataclasses.replace(ex, scheme=CommScheme("persistent"))
        base = run_sweep(wl, K_, solver, algorithm, base_ex.spec)
        sweep = HSweep(
            eps=base.eps, n_local=base.n_local, t_ref_s=base.t_ref_s,
            points=list(base.points), algorithm=algorithm,
            scheme=ex.scheme.name, mode=ex.mode.spec,
            comm_bytes_per_round=bench_trainer(
                wl, algorithm, base.n_local, solver, K_,
                exchange=ex).comm_bytes_per_round(),
            exchange=ex.spec, workers=K_)
        _SWEEPS[key] = sweep
        return sweep
    nl = n_local(wl, K_)
    eps = sweep_eps(wl, algorithm)
    grid = (wl.sgd_h_grid if algorithm == "minibatch_sgd"
            else h_grid(wl, K_))
    sweep = HSweep(eps=eps, n_local=nl, algorithm=algorithm,
                   scheme=ex.scheme.name, mode=ex.mode.spec,
                   exchange=ex.spec, workers=K_)
    for H in grid:
        tr = bench_trainer(wl, algorithm, H, solver, K_, exchange=ex)
        hist = (tr.run_workers(wl.max_rounds, record_every=1, target_eps=eps)
                if algorithm == "minibatch_sgd"
                else tr.run(wl.max_rounds, record_every=1, target_eps=eps))
        t_s = measure_solver_time(tr, H, reps=wl.reps) / K_
        sweep.points.append(HSweepPoint(H, hist.rounds_to(eps), t_s))
        sweep.comm_bytes_per_round = tr.comm_bytes_per_round()
    sweep.t_ref_s = measure_solver_time(
        bench_trainer(wl, algorithm, nl, solver, K_, exchange=ex), nl,
        reps=wl.reps) / K_
    _SWEEPS[key] = sweep
    return sweep


def assert_rounds_in_band(wl: Workload, sweep: HSweep) -> list[str]:
    """Smoke-tier convergence sanity: every grid point reaches eps, the
    H = n_local point lands in the calibrated band, and more local work
    never needs (materially) more rounds. Returns human-readable notes;
    raises AssertionError when the band is violated."""
    notes = []
    lo, hi = wl.rounds_band
    for pt in sweep.points:
        assert pt.rounds_to_eps is not None, (
            f"H={pt.H} did not reach eps={wl.eps} in {wl.max_rounds} rounds")
    ref = next((p for p in sweep.points if p.H == sweep.n_local), None)
    if ref is not None:
        assert lo <= ref.rounds_to_eps <= hi, (
            f"rounds_to_eps at H=n_local is {ref.rounds_to_eps}, outside "
            f"the calibrated band [{lo}, {hi}]")
        notes.append(f"rounds_to_eps(H=n_local)={ref.rounds_to_eps} "
                     f"within band [{lo}, {hi}]")
    by_h = sorted(sweep.points, key=lambda p: p.H)
    assert by_h[-1].rounds_to_eps <= 1.2 * by_h[0].rounds_to_eps + 2, (
        f"more local work should not need more rounds: "
        f"H={by_h[0].H} -> {by_h[0].rounds_to_eps}, "
        f"H={by_h[-1].H} -> {by_h[-1].rounds_to_eps}")
    notes.append("rounds-to-eps monotone-ish in H "
                 f"({by_h[0].rounds_to_eps} -> {by_h[-1].rounds_to_eps})")
    return notes
