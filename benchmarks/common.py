"""Shared setup for the paper-figure benchmarks.

The workload mirrors the paper's webspam ridge regression at CPU-feasible
scale (see DESIGN.md §2): K=8 workers, eps=1e-3, H in fractions of
n_local, overhead profiles (A)-(E) calibrated to Fig 3.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import CoCoAConfig, CoCoATrainer
from repro.core.tradeoff import HSweep, HSweepPoint, measure_solver_time
from repro.data import make_glm_data

EPS = 1e-3
K = 8
M, N = 512, 2048
LAM = 1.0
H_FRACS = (0.05, 0.2, 1.0, 4.0, 16.0)   # x n_local, the paper's Fig-6 axis
RESULTS_DIR = os.environ.get("BENCH_OUT", "results/bench")


def emit(name: str, rows: list[dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    keys = list(rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(str(r[k]) for k in keys))
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# -> {path}")
    for line in lines:
        print(line)


_CACHE: dict = {}


def problem():
    if "data" not in _CACHE:
        _CACHE["data"] = make_glm_data(m=M, n=N, density=0.15, zipf_a=1.1,
                                       seed=42)
    return _CACHE["data"]


def n_local() -> int:
    return N // K


def h_grid() -> list[int]:
    return [max(1, int(f * n_local())) for f in H_FRACS]


def trainer(H: int, solver: str = "scd_kernel", K_: int = K,
            seed: int = 0) -> CoCoATrainer:
    A, b, _ = problem()
    return CoCoATrainer(
        CoCoAConfig(K=K_, H=H, lam=LAM, eta=1.0, solver=solver, seed=seed),
        A, b)


def run_sweep(K_: int = K, solver: str = "scd_kernel",
              max_rounds: int = 1500) -> HSweep:
    """Measured rounds-to-eps + solver wall time per H (paper Fig 6 raw).

    The K virtual workers execute SERIALLY on this 1-core host, so the
    measured per-round solver time is divided by K to model the real
    cluster where workers run concurrently (the paper's setting).
    """
    A, b, _ = problem()
    nl = int(np.ceil(N / K_))
    sweep = HSweep(eps=EPS, n_local=nl)
    for frac in H_FRACS:
        H = max(1, int(frac * nl))
        tr = trainer(H, solver, K_)
        hist = tr.run(max_rounds, record_every=1, target_eps=EPS)
        t_s = measure_solver_time(tr, H, reps=2) / K_
        sweep.points.append(HSweepPoint(H, hist.rounds_to(EPS), t_s))
    sweep.t_ref_s = measure_solver_time(trainer(nl, solver, K_), nl,
                                        reps=2) / K_
    return sweep
