"""Roofline table: reads results/roofline/*.json produced by
`python -m repro.launch.roofline --all` (run separately with the
512-device flag) and prints §Roofline rows."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common


def main() -> list[dict]:
    files = sorted(glob.glob("results/roofline/*.json"))
    if not files:
        print("# no roofline results found — run "
              "`PYTHONPATH=src python -m repro.launch.roofline --all` first")
        return []
    rows = []
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "compute_s": "-", "memory_s": "-",
                         "collective_s": "-", "dominant": r["status"],
                         "useful_flops_ratio": "-"})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": round(r["compute_s"], 5),
            "memory_s": round(r["memory_s"], 5),
            "collective_s": round(r["collective_s"], 5),
            "dominant": r["dominant"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
        })
    common.emit("roofline", rows)
    return rows


if __name__ == "__main__":
    main()
