"""Roofline table: reads results/roofline/*.json produced by
`python -m repro.launch.roofline --all` (run separately with the
512-device flag) and reports §Roofline rows. Skips cleanly (status
"skipped") when no artifacts exist — the smoke tier does not run the
512-device dry-run."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark

ROOFLINE_DIR = os.environ.get("ROOFLINE_OUT", "results/roofline")


@benchmark("roofline", figures="§roofline",
           description="roofline table from launch.roofline artifacts")
def run(ctx: BenchContext) -> dict:
    files = sorted(glob.glob(os.path.join(ROOFLINE_DIR, "*.json")))
    if not files:
        return {"status": "skipped",
                "params": {"roofline_dir": ROOFLINE_DIR},
                "notes": ["no roofline results found — run "
                          "`PYTHONPATH=src python -m repro.launch.roofline "
                          "--all` first"]}
    rows, timings, counters = [], {}, {}
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "compute_s": "-", "memory_s": "-",
                         "collective_s": "-", "dominant": r["status"],
                         "useful_flops_ratio": "-"})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": round(r["compute_s"], 5),
            "memory_s": round(r["memory_s"], 5),
            "collective_s": round(r["collective_s"], 5),
            "dominant": r["dominant"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
        })
        tag = f"{r['arch']}_{r['shape']}"
        timings[f"roofline_total_{tag}"] = (r["compute_s"] + r["memory_s"]
                                            + r["collective_s"])
        counters[f"useful_flops_ratio_{tag}"] = r["useful_flops_ratio"]
    return {"params": {"roofline_dir": ROOFLINE_DIR, "files": len(files)},
            "timings_s": timings, "counters": counters, "rows": rows,
            "notes": []}


def main() -> list[dict]:
    out = run(BenchContext(tier="full"))
    for note in out["notes"]:
        print(f"# {note}")
    if out.get("rows"):
        common.emit("roofline", out["rows"])
    return out.get("rows", [])


if __name__ == "__main__":
    main()
