"""Paper Fig 3 + Fig 4: execution-time decomposition per implementation.

T_worker is MEASURED (our Pallas SCD solver plays the C++ module, scaled
by the calibrated compute multipliers for Scala/Python); T_overhead is
the calibrated framework overhead; T_master is measured (the w-update).
``decomp_rounds`` rounds at H = n_local, the paper's measurement setting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.bench.registry import BenchContext, benchmark
from repro.bench.timing import TimingPolicy, time_callable
from repro.core import PROFILES
from repro.core.overheads import communicated_bytes_per_round
from repro.core.tradeoff import measure_solver_time

ORDER = ("A_spark", "B_spark_c", "C_pyspark", "D_pyspark_c", "E_mpi")
OPT = ("B_spark_opt", "D_pyspark_opt")


def _measure_master_time(wl: common.Workload, reps: int) -> float:
    """The master's work: summing K m-vectors + the w update."""
    dv = jnp.ones((wl.K, wl.m), jnp.float32)
    w = jnp.zeros((wl.m,), jnp.float32)
    f = jax.jit(lambda w, dv: w + dv.sum(0))
    return time_callable(f, w, dv, policy=TimingPolicy(warmup=1,
                                                       reps=max(reps, 3) * 10))


@benchmark("overheads", figures="Fig 3-4",
           description="T_tot decomposition per implementation (A)-(E)")
def run(ctx: BenchContext) -> dict:
    wl = common.workload(ctx.tier)
    reps = ctx.repeats or wl.reps
    rounds = wl.decomp_rounds
    nl = common.n_local(wl)
    tr = common.trainer(wl, nl)
    t_ref = measure_solver_time(tr, nl, reps=reps)
    t_master = _measure_master_time(wl, reps)
    rows, timings, counters = [], {}, {}
    for name in ORDER + OPT:
        p = PROFILES[name]
        t_worker = p.compute_mult * t_ref * rounds
        t_overhead = p.overhead_units * t_ref * rounds
        t_total = t_worker + t_overhead + t_master * rounds
        comm = communicated_bytes_per_round(
            wl.m, wl.n, wl.K, p.persistent_alpha)
        rows.append({
            "impl": name,
            "t_worker_s": round(t_worker, 5),
            "t_master_s": round(t_master * rounds, 6),
            "t_overhead_s": round(t_overhead, 5),
            "t_total_s": round(t_total, 5),
            "overhead_frac": round(t_overhead / (t_worker + t_overhead), 3),
            "comm_bytes_per_round": comm,
        })
        timings[f"t_total_{name}"] = t_total
        counters[f"comm_bytes_per_round_{name}"] = comm
    timings["t_ref_solver"] = t_ref
    timings["t_master_step"] = t_master

    # paper-claim checks
    by = {r["impl"]: r for r in rows}
    ratio = by["C_pyspark"]["t_overhead_s"] / by["A_spark"]["t_overhead_s"]
    mpi_frac = by["E_mpi"]["t_overhead_s"] / by["E_mpi"]["t_total_s"]
    r1 = by["B_spark_c"]["t_overhead_s"] / by["B_spark_opt"]["t_overhead_s"]
    r2 = by["D_pyspark_c"]["t_overhead_s"] / by["D_pyspark_opt"]["t_overhead_s"]
    notes = [
        f"pySpark/Spark overhead ratio = {ratio:.1f}x (paper: 15x)",
        f"MPI overhead fraction = {mpi_frac:.3f} (paper: ~0.03)",
        f"persistent-mem+meta-RDD overhead cuts: Scala {r1:.1f}x (paper 3x), "
        f"Python {r2:.1f}x (paper 10x)",
    ]
    counters["pyspark_spark_overhead_ratio"] = round(ratio, 2)
    counters["mpi_overhead_fraction"] = round(mpi_frac, 4)
    return {"params": {"m": wl.m, "n": wl.n, "K": wl.K, "rounds": rounds,
                       "H": nl},
            "timings_s": timings, "counters": counters,
            "rows": rows, "notes": notes}


def main() -> list[dict]:
    """Standalone CLI (legacy): full tier + the CSV emitter."""
    out = run(BenchContext(tier="full"))
    common.emit("fig3_fig4_overheads", out["rows"])
    for note in out["notes"]:
        print(f"# {note}")
    return out["rows"]


if __name__ == "__main__":
    main()
