"""Paper Fig 3 + Fig 4: execution-time decomposition per implementation.

T_worker is MEASURED (our Pallas SCD solver plays the C++ module, scaled
by the calibrated compute multipliers for Scala/Python); T_overhead is
the calibrated framework overhead; T_master is measured (the w-update).
100 rounds at H = n_local, exactly the paper's measurement setting.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import PROFILES
from repro.core.overheads import communicated_bytes_per_round
from repro.core.tradeoff import measure_solver_time

ROUNDS = 100
ORDER = ("A_spark", "B_spark_c", "C_pyspark", "D_pyspark_c", "E_mpi")
OPT = ("B_spark_opt", "D_pyspark_opt")


def _measure_master_time() -> float:
    """The master's work: summing K m-vectors + the w update."""
    dv = jnp.ones((common.K, common.M), jnp.float32)
    w = jnp.zeros((common.M,), jnp.float32)
    f = jax.jit(lambda w, dv: w + dv.sum(0))
    f(w, dv).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        w = f(w, dv)
    w.block_until_ready()
    return (time.perf_counter() - t0) / 50


def main(optimized: bool = True) -> list[dict]:
    nl = common.n_local()
    tr = common.trainer(nl)
    t_ref = measure_solver_time(tr, nl, reps=2)
    t_master = _measure_master_time()
    rows = []
    for name in ORDER + (OPT if optimized else ()):
        p = PROFILES[name]
        t_worker = p.compute_mult * t_ref * ROUNDS
        t_overhead = p.overhead_units * t_ref * ROUNDS
        comm = communicated_bytes_per_round(
            common.M, common.N, common.K, p.persistent_alpha)
        rows.append({
            "impl": name,
            "t_worker_s": round(t_worker, 3),
            "t_master_s": round(t_master * ROUNDS, 4),
            "t_overhead_s": round(t_overhead, 3),
            "t_total_s": round(t_worker + t_overhead + t_master * ROUNDS, 3),
            "overhead_frac": round(t_overhead / (t_worker + t_overhead), 3),
            "comm_bytes_per_round": comm,
        })
    common.emit("fig3_fig4_overheads", rows)
    # paper-claim checks
    by = {r["impl"]: r for r in rows}
    ratio = by["C_pyspark"]["t_overhead_s"] / by["A_spark"]["t_overhead_s"]
    print(f"# pySpark/Spark overhead ratio = {ratio:.1f}x (paper: 15x)")
    mpi_frac = by["E_mpi"]["t_overhead_s"] / by["E_mpi"]["t_total_s"]
    print(f"# MPI overhead fraction = {mpi_frac:.3f} (paper: ~0.03)")
    if optimized:
        r1 = by["B_spark_c"]["t_overhead_s"] / by["B_spark_opt"]["t_overhead_s"]
        r2 = by["D_pyspark_c"]["t_overhead_s"] / by["D_pyspark_opt"]["t_overhead_s"]
        print(f"# persistent-mem+meta-RDD overhead cuts: Scala {r1:.1f}x "
              f"(paper 3x), Python {r2:.1f}x (paper 10x)")
    return rows


if __name__ == "__main__":
    main()
