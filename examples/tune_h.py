"""The paper's conclusion, automated: an algorithm that adapts its
communication interval to measured system conditions.

Uses the golden-section autotuner over live measurements — rounds-to-eps
from real runs plus a per-round time model whose solver-cost slope is
MEASURED through the bench harness's timing discipline
(``repro.bench.timing``, warmup/repeat/min) rather than hard-coded —
then checks the tuned H against the exhaustive grid, for two very
different "systems" (MPI-like and pySpark-like).

``--mode stale`` runs the one-round-delayed apply (the staleness knob):
rounds-to-eps is measured on the actual stale trajectories and the time
model hides ``min(t_comm, t_compute)`` per round, so the tuner sees both
the convergence tax and the overlap payoff.

``--codec int8|int4`` runs the exchange through the compressed
transport with that wire codec (``exchange="compressed:<codec>"``):
rounds-to-eps is measured on the actual quantized trajectories and the
time model charges the codec's smaller wire bytes, so the tuner sees
both sides of the compression trade too.

``--straggler`` tags the exchange with a straggler profile (e.g.
``mix(p=0.5,slow=16)``). Straggling never changes the measured
trajectory (the BSP barrier makes it time-only), but the time model
charges E[max over K workers] x the solver time — watch the tuned H
drop as the barrier makes framework overhead relatively cheaper.

  PYTHONPATH=src python examples/tune_h.py
  PYTHONPATH=src python examples/tune_h.py --mode stale --bandwidth 1e8
  PYTHONPATH=src python examples/tune_h.py --codec int4 --bandwidth 1e8
  PYTHONPATH=src python examples/tune_h.py --straggler "mix(p=0.5,slow=16)"
"""
import argparse
import functools

from repro.bench.timing import measure_solver_time, synthetic_link
from repro.core import CoCoAConfig, CoCoATrainer, PROFILES
from repro.core.tradeoff import TimeModel, autotune_H
from repro.data import make_glm_data

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--mode", choices=("sync", "stale"), default="sync",
                help="exchange mode: sync (bulk-synchronous) or stale "
                     "(one-round-delayed apply)")
ap.add_argument("--bandwidth", type=float, default=1e9,
                help="synthetic link bandwidth in B/s for the comm term "
                     "(default 1 GB/s)")
ap.add_argument("--codec",
                choices=("f32", "int8", "int4", "int2", "topk",
                         "ef:int8", "ef:int4", "ef:int2", "ef:topk"),
                default="f32",
                help="wire codec for the update exchange: f32 keeps the "
                     "exact persistent psum; int8/int4 run the "
                     "compressed transport with that codec")
ap.add_argument("--straggler", default=None, metavar="KIND(...)",
                help="straggler profile segment, e.g. 'det(slow=4)' or "
                     "'mix(p=0.5,slow=16)' — time-only, charged by the "
                     "time model's barrier term")
args = ap.parse_args()
SCHEME = ("persistent" if args.codec == "f32"
          else f"compressed:{args.codec}")
# one ExchangeConfig spec carries the whole exchange: transport:codec /
# mode / straggler profile
EXCHANGE = SCHEME + ("" if args.mode == "sync" else f"/{args.mode}") + (
    "" if args.straggler is None else f"/straggler:{args.straggler}")

A, b, _ = make_glm_data(m=256, n=768, density=0.2, seed=4)
# the target tolerance follows the codec's quantization noise floor:
# int8's absmax grid converges through 1e-3 on this problem, int4's
# ~17x-coarser grid plateaus near 2e-2, so its tuner runs at the
# coarse tolerance the codec can actually reach; int2 and plain topk
# floor higher still, while the ef: wrapper's error feedback restores
# the BASE tolerance for every lossy codec it wraps
EPS = {"f32": 1e-3, "int8": 1e-3, "int4": 5e-2,
       "int2": 5e-1, "topk": 5e-1}.get(args.codec, 1e-3)  # ef:* = base
H_REF = 96

# Measure the solver-cost slope once (seconds per local SCD step) at the
# reference point; the model extrapolates linearly in H, which is exact
# for this solver (H sequential coordinate steps).
_tr = CoCoATrainer(CoCoAConfig(K=8, H=H_REF, seed=0, exchange=EXCHANGE),
                   A, b)
T_PER_STEP = measure_solver_time(_tr, H_REF, reps=3) / H_REF
T_REF = T_PER_STEP * H_REF
COMM_BYTES = _tr.comm_bytes_per_round()
LINK = synthetic_link(args.bandwidth, 1e-4)
print(f"measured solver cost: {T_PER_STEP * 1e6:.2f} us/step "
      f"(t_ref={T_REF * 1e3:.2f} ms at H={H_REF}); "
      f"exchange={EXCHANGE}, {COMM_BYTES} B/round over a "
      f"{args.bandwidth / 1e9:.2f} GB/s link")


@functools.lru_cache(maxsize=64)
def rounds_to_eps(H: int):
    tr = CoCoATrainer(CoCoAConfig(K=8, H=H, seed=0, exchange=EXCHANGE),
                      A, b)
    return tr.run(800, record_every=1, target_eps=EPS).rounds_to(EPS)


def round_time_model(model, H):
    return model.round_time(T_PER_STEP * H, t_ref_s=T_REF)


for name in ("E_mpi", "D_pyspark_c"):
    model = TimeModel(PROFILES[name], COMM_BYTES, LINK, exchange=EXCHANGE,
                      workers=8)
    h_star = autotune_H(rounds_to_eps,
                        functools.partial(round_time_model, model), 4, 4096)
    grid = [8, 32, 96, 384, 1536, 4096]
    costs = {H: (rounds_to_eps(H) or 10**9) * round_time_model(model, H)
             for H in grid}
    h_grid = min(costs, key=costs.get)
    cost_star = ((rounds_to_eps(h_star) or 10**9)
                 * round_time_model(model, h_star))
    print(f"{name:14s} autotuned H = {h_star:5d} "
          f"(cost {cost_star:7.2f}s) vs grid best H = {h_grid:5d} "
          f"(cost {costs[h_grid]:7.2f}s)")
    assert cost_star <= 2.0 * costs[h_grid]
print("autotuner tracks the per-system optimum — 'algorithms that adapt "
      "their parameters to system conditions' (paper §6)")
