"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

This instantiates tinyllama at ~100M scale (trimmed layers/width, real
vocab), runs the full training substrate (AdamW + cosine schedule +
per-layer remat + checkpointing), and reports the loss curve.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.checkpoint import save_checkpoint
from repro.data.tokens import TokenStream
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.train import make_train_step
from repro.utils.trees import tree_params

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

# ~100M-param member of the tinyllama family
cfg = dataclasses.replace(get_config("tinyllama-1.1b"),
                          num_layers=8, d_model=640, num_heads=10,
                          num_kv_heads=2, head_dim=64, d_ff=1792)
model = build_model(cfg)
params = model.init(jax.random.key(0))
print(f"model: {cfg.name} trimmed to {tree_params(params)/1e6:.1f}M params")

opt_cfg = AdamWConfig(lr=6e-4)
opt = adamw_init(params, opt_cfg)
step = jax.jit(make_train_step(model, opt_cfg, remat=True))
ts = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)

t0 = time.time()
first = None
for i in range(args.steps):
    batch = {k: jnp.asarray(v) for k, v in ts.next_batch().items()}
    params, opt, m = step(params, opt, batch)
    if first is None:
        first = float(m["loss"])
    if i % 20 == 0 or i == args.steps - 1:
        toks = (i + 1) * args.batch * args.seq
        print(f"step {i:4d} loss={float(m['loss']):.4f} "
              f"acc={float(m['accuracy']):.3f} "
              f"({toks / max(time.time() - t0, 1e-9):.0f} tok/s)")
save_checkpoint("/tmp/train_lm_ckpt.npz", {"params": params}, step=args.steps)
print(f"loss {first:.3f} -> {float(m['loss']):.3f}; "
      f"checkpoint at /tmp/train_lm_ckpt.npz")
assert float(m["loss"]) < first, "loss must decrease"
