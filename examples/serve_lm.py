"""Serving example: batched prefill + greedy decode across families —
dense (KV cache), SSM (recurrent state), hybrid (ring buffer + LRU).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import greedy_generate

for arch in ("tinyllama-1.1b", "mamba2-2.7b", "recurrentgemma-9b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(0, 100, (4, 24)), jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompts, max_new=12)
    dt = time.time() - t0
    print(f"{arch:22s} generated {out.shape[0]}x{out.shape[1]} tokens "
          f"in {dt:5.1f}s; sample: {np.asarray(out[0])[:8]}")
print("all three state families (KV cache / SSM state / LRU+ring) decode OK")
