"""Quickstart: the paper's workload end-to-end in ~a minute on CPU.

Trains elastic-net ridge regression with CoCoA (Pallas-kernel local
solver), compares the communication schemes, shows the H trade-off
under two framework-overhead profiles, walks the unified
distributed-driver layer's 3-algorithm x 4-scheme matrix, flips the
staleness knob (`exchange="stale"`), and runs the straggler / elastic
membership regimes through the same one-string `ExchangeConfig` spec.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (COMM_SCHEMES, CoCoAConfig, CoCoATrainer,
                        MinibatchSCD, MinibatchSGD, PROFILES, SGDConfig)
from repro.core.glm import ridge_exact
from repro.core.tradeoff import HSweep, HSweepPoint, optimal_H
from repro.data import make_glm_data

# 1. synthetic webspam-like data, column-partitioned over 8 workers
A, b, _ = make_glm_data(m=384, n=1024, density=0.15, seed=0)
print(f"data: A {A.shape}, 8 workers, lam=1.0 (ridge)")

# 2. CoCoA with the Pallas SCD kernel as the local solver
cfg = CoCoAConfig(K=8, H=256, lam=1.0, eta=1.0, solver="scd_kernel")
tr = CoCoATrainer(cfg, A, b)
hist = tr.run(rounds=100, record_every=10, target_eps=1e-3)
print("suboptimality trace:", [f"{s:.1e}" for s in hist.subopt])

# 3. verify against the closed-form ridge solution
alpha_star = ridge_exact(A, b, 1.0)
rel = np.linalg.norm(tr.alpha_final - alpha_star) / np.linalg.norm(alpha_star)
print(f"||alpha - alpha*|| / ||alpha*|| = {rel:.2e}")

# 4. the paper's point: optimal H depends on the framework's overhead
sweep = HSweep(eps=1e-3, n_local=128, t_ref_s=0.05)
for H in (8, 32, 128, 512, 2048):
    c = CoCoAConfig(K=8, H=H, solver="scd_ref")
    h = CoCoATrainer(c, A, b).run(800, record_every=1, target_eps=1e-3)
    sweep.points.append(HSweepPoint(H, h.rounds_to(1e-3), H * 4e-4))
for name in ("E_mpi", "B_spark_c", "D_pyspark_c"):
    h_opt, t_opt = optimal_H(PROFILES[name], sweep)
    print(f"{name:14s} optimal H = {h_opt:5d}  time-to-1e-3 = {t_opt:7.2f}s")
print("=> higher framework overhead pushes the optimum toward more local "
      "computation — the paper's central result.")

# 5. the unified distributed-driver layer: all three algorithms (§5.4)
#    under the canonical communication schemes plus the packed-int4
#    codec cell, with per-round traffic sized to what the collectives
#    actually move (codec wire bytes for `compressed[:codec]`).
#    CoCoA all-reduces an m-vector, mini-batch SGD an n-vector — more
#    bytes whenever n > m, one reason CoCoA wins in the paper's Fig 5.
print(f"\n{'algorithm':14s} {'scheme':15s} {'eps':>5s} {'rounds':>7s} "
      f"{'bytes/round':>12s}")
for algo in ("cocoa", "minibatch_scd", "minibatch_sgd"):
    for scheme in COMM_SCHEMES + ("compressed:int4",):
        # int4's ~17x-coarser grid plateaus above 1e-2 here: its honest
        # trade is early progress per byte, so it runs at a coarse eps
        eps = 1e-1 if scheme.endswith("int4") else 1e-2
        if algo == "minibatch_sgd":
            tr = MinibatchSGD(SGDConfig(step_size=0.1, K=8, lam=1.0,
                                        exchange=scheme), A, b)
            h = tr.run_workers(300, record_every=1, target_eps=eps)
        else:
            cls = MinibatchSCD if algo == "minibatch_scd" else CoCoATrainer
            tr = cls(CoCoAConfig(K=8, H=128, exchange=scheme), A, b)
            h = tr.run(300, record_every=1, target_eps=eps)
        print(f"{algo:14s} {scheme:15s} {eps:>5g} "
              f"{str(h.rounds_to(eps)):>7s} "
              f"{tr.comm_bytes_per_round():>12d}")
print("=> same math per algorithm under every scheme; `compressed` "
      "(the :int8 alias) moves ~4x fewer bytes, `compressed:int4` ~8x, "
      "`spark_faithful` pays for shipping alpha.")

# 6. the staleness knob (§4-§5): `stale` applies each aggregate one
#    round late — same wire bytes, a (problem-dependent) convergence
#    tax, and an exchange that can hide behind the next round's compute
#    (the TimeModel charges max(0, t_comm - t_compute) per stale round).
#    `stale:k=2` bounds the staleness at two rounds instead of one.
for mode in ("sync", "stale", "stale:k=2"):
    tr = CoCoATrainer(CoCoAConfig(K=8, H=128, exchange=mode), A, b)
    h = tr.run(300, record_every=1, target_eps=1e-2)
    print(f"cocoa/{mode:9s}: rounds->1e-2 = {h.rounds_to(1e-2):3d}, "
          f"bytes/round = {tr.comm_bytes_per_round()}")
print("=> same wire bytes either way, but stale rounds never wait on "
      "the wire — the paper's scheduling-delay regime as a knob.")

# 7. stragglers and elastic membership, in the same one-string spec:
#    a straggler profile never changes the math (the BSP barrier makes
#    straggling a wall-clock effect the TimeModel charges as
#    E[max over K workers]); a `drop:w@d-r` event really removes worker
#    w's updates for rounds d..r and shrinks the live-round traffic.
from repro.core.tradeoff import TimeModel  # noqa: E402
from repro.bench.timing import synthetic_link  # noqa: E402

base = CoCoATrainer(CoCoAConfig(K=8, H=128), A, b)
slow = CoCoATrainer(CoCoAConfig(
    K=8, H=128, exchange="persistent/straggler:mix(p=0.25,slow=8)"), A, b)
h_base = base.run(300, record_every=1, target_eps=1e-2)
h_slow = slow.run(300, record_every=1, target_eps=1e-2)
assert h_base.rounds_to(1e-2) == h_slow.rounds_to(1e-2)  # time-only!
link = synthetic_link(1e9, 1e-4)
for tr, tag in ((base, "no stragglers"), (slow, "mix(p=0.25,slow=8)")):
    tm = TimeModel(PROFILES["E_mpi"], tr.comm_bytes_per_round(), link,
                   exchange=tr.exchange, workers=8)
    print(f"cocoa {tag:20s}: barrier x{tm.barrier_mult:5.2f}, "
          f"round_time(50ms solver) = "
          f"{tm.round_time(0.05, 0.05) * 1e3:6.1f} ms")

el = CoCoATrainer(CoCoAConfig(K=8, H=128,
                              exchange="persistent/drop:3@2-4"), A, b)
h = el.run(300, record_every=1, target_eps=1e-2)
print(f"cocoa elastic drop:3@2-4: rounds->1e-2 = {h.rounds_to(1e-2)}, "
      f"bytes full = {el.comm_bytes_per_round()}, "
      f"at t=2 (7/8 live) = {el.comm_bytes_per_round(t=2)}")
print("=> one grammar for the whole exchange: "
      "transport:codec / backend / stale:k / straggler:kind(...) / "
      "drop:w@d-r")

# 8. the collective-backend axis: the SAME exchange on a different
#    fabric. `ring` runs the reduce-scatter + all-gather explicitly via
#    lax.ppermute (codec-encoded parts for `compressed`), so it prices
#    what the fused collective hides: the link latency is paid per HOP
#    (2(K-1) charges for the sum transports) — the term that shifts the
#    tuned H *up* on latency-bound links. Numerics are pinned: the
#    compressed/spark_faithful rings are bit-identical to xla, the
#    in-place sums agree to float tolerance. launch/dist.py runs the
#    same specs across real processes (jax.distributed + gloo).
for spec in ("persistent", "persistent/ring", "compressed:int4/ring"):
    tr = CoCoATrainer(CoCoAConfig(K=8, H=128, exchange=spec), A, b)
    tm = TimeModel(PROFILES["E_mpi"], tr.comm_bytes_per_round(), link,
                   exchange=tr.exchange, workers=8)
    print(f"cocoa {spec:20s}: bytes/round = {tr.comm_bytes_per_round():6d}, "
          f"comm = {tm.comm_time_s() * 1e3:6.2f} ms")
print("=> same update, different fabric: the backend segment swaps the "
      "collective implementation without touching the algorithm.")
