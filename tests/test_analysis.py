"""repro.analysis: collective-graph lifting (corpus-pinned), traffic
derivation, lint rules, and the AST source lint."""
import os
import types

import pytest

from repro.analysis import cells as acells
from repro.analysis import pylint_jax, rules  # noqa: F401  (registers rules)
from repro.analysis.findings import RULES, Finding, max_severity
from repro.analysis.graph import (Shape, _iota_replica_groups, lift_hlo,
                                  parse_shapes)
from repro.analysis.traffic import (derived_round_traffic,
                                    quantized_wire_dtypes)
from repro.utils.hlo import parse_collectives

CORPUS = os.path.join(os.path.dirname(__file__), "data", "hlo")


def corpus(name: str) -> str:
    with open(os.path.join(CORPUS, name)) as f:
        return f.read()


def _exchange(transport="persistent", backend="xla"):
    """Duck-typed stand-in for ExchangeConfig (traffic reads only
    .backend and .scheme.transport)."""
    return types.SimpleNamespace(
        backend=backend, scheme=types.SimpleNamespace(transport=transport))


# ---------------------------------------------------------------------------
# graph lifting on the checked-in corpus (real jax 0.4 CPU HLO)
# ---------------------------------------------------------------------------

def test_int8_gather_per_op_records():
    g = lift_hlo(corpus("int8_gather.txt"))
    assert g.by_kind() == {"all-gather": (2, 100, 400),
                           "all-reduce": (1, 4, 4)}
    payload = next(op for op in g.ops("all-gather")
                   if "s8" in op.operand_dtypes)
    assert payload.operand_shapes == (Shape("s8", (1, 96)),)
    assert payload.result_shapes == (Shape("s8", (4, 96)),)
    assert payload.operand_bytes == 96 and payload.result_bytes == 384
    assert payload.replica_groups == ((0, 1, 2, 3),)
    scale = next(op for op in g.ops("all-gather") if op is not payload)
    assert scale.operand_shapes == (Shape("f32", (1,)),)
    # channel ids are per-op and unique across the module
    chans = [op.channel_id for op in g.collectives]
    assert None not in chans and len(set(chans)) == len(chans)


def test_int8_gather_decode_dataflow():
    g = lift_hlo(corpus("int8_gather.txt"))
    payload = next(op for op in g.ops("all-gather")
                   if "s8" in op.operand_dtypes)
    down = g.downstream([payload.name], depth=1)
    decode = next(i for i in down if i.op == "fusion")
    # the gather-side decode materializes the K-stacked f32 update in
    # HBM — the inefficiency the f32-intermediate rule flags
    assert decode.result_shapes == (Shape("f32", (4, 96)),)
    assert decode.result_bytes == 4 * 96 * 4


def test_ring_int4_pairs_and_bytes():
    g = lift_hlo(corpus("ring_int4.txt"))
    cps = g.ops("collective-permute")
    assert len(cps) == 6
    ring = ((0, 1), (1, 2), (2, 3), (3, 0))
    assert all(op.source_target_pairs == ring for op in cps)
    sizes = sorted(op.operand_bytes for op in cps)
    assert sizes == [4, 4, 4, 48, 48, 48]  # 3 f32[] scale + 3 u8[48] hops
    assert quantized_wire_dtypes(g) == {"u8"}
    assert len(g.ops("all-reduce")) == 1  # the scalar metric psum


def test_async_start_done_counted_once():
    g = lift_hlo(corpus("async_pair.txt"))
    # -start counts, -done doesn't; the start op's tuple result drops
    # the operand alias (the old parser summed 96+384 floats there)
    assert g.total_count == 2
    ag = g.ops("all-gather")[0]
    assert ag.asynchronous
    assert ag.operand_bytes == 96 * 4
    assert ag.result_bytes == 384 * 4
    ar = g.ops("all-reduce")[0]
    assert ar.operand_bytes == ar.result_bytes == 96 * 4


def test_int4_wire_dtypes_sized_in_bits():
    g = lift_hlo(corpus("int4_wire.txt"))
    ag = g.ops("all-gather")[0]
    assert ag.operand_bytes == 48      # s4[96]: 96 * 4 bits = 48 bytes
    assert ag.result_bytes == 192      # s4[384]
    cp = g.ops("collective-permute")[0]
    assert cp.operand_bytes == 48      # u4[95]: ceil(95 * 4 / 8)
    assert quantized_wire_dtypes(g) == {"s4", "u4"}


def test_tuple_layout_result_sized_correctly():
    g = lift_hlo(corpus("tuple_layout.txt"))
    rs = g.ops("reduce-scatter")[0]
    ag = g.ops("all-gather")[0]
    assert (rs.operand_bytes, rs.result_bytes) == (384, 96)
    assert (ag.operand_bytes, ag.result_bytes) == (96, 384)
    # iota replica-group form expands to the literal groups
    assert rs.replica_groups == ((0, 1, 2, 3),)
    # the tuple result whose layouts contain parens ({0:T(256)}) is
    # sized as both elements — the old one-regex scan truncated it
    assert g.instructions["out"].result_bytes == 96 + 384


def test_iota_replica_groups_expansion():
    assert _iota_replica_groups((2, 4), (8,), None) == \
        ((0, 1, 2, 3), (4, 5, 6, 7))
    # [2,4]<=[2,4]T(1,0): transpose the 2x4 iota before grouping
    assert _iota_replica_groups((2, 4), (2, 4), (1, 0)) == \
        ((0, 4, 1, 5), (2, 6, 3, 7))


def test_parse_shapes_scalar_and_unknown():
    shapes = parse_shapes("(f32[], pred[3], token[])")
    assert shapes == (Shape("f32", ()), Shape("pred", (3,)))
    assert shapes[0].bytes == 4


def test_parse_collectives_is_graph_aggregate():
    for name in ("int8_gather.txt", "ring_int4.txt", "async_pair.txt",
                 "int4_wire.txt", "tuple_layout.txt"):
        txt = corpus(name)
        stats = parse_collectives(txt)
        assert stats.by_kind == lift_hlo(txt).by_kind()


# ---------------------------------------------------------------------------
# traffic derivation (the single owner bench_drivers delegates to)
# ---------------------------------------------------------------------------

def test_derived_traffic_master_centric():
    g = lift_hlo(corpus("int8_gather.txt"))
    # payload operands (96 + 4) each way for K workers; the 4-byte
    # metric psum excluded
    assert derived_round_traffic(g, _exchange("compressed"), 4) == \
        2 * 4 * 100
    assert derived_round_traffic(g, _exchange("compressed"), 1) == 0


def test_derived_traffic_reduce_scatter():
    g = lift_hlo(corpus("tuple_layout.txt"))
    ex = _exchange("reduce_scatter")
    assert derived_round_traffic(g, ex, 4) == 3 * 384 + 4 * 3 * 96


def test_derived_traffic_ring():
    g = lift_hlo(corpus("ring_int4.txt"))
    ex = _exchange("compressed", backend="ring")
    assert derived_round_traffic(g, ex, 4) == 4 * (3 * 48 + 3 * 4)


def test_padded_len_single_owner():
    from repro.analysis import traffic
    from repro.comm import collectives as comm
    # the analyzer must not grow its own padding formula
    assert traffic.padded_len is comm.padded_len
    # and the modelled reduce-scatter bytes really use it: (K-1)/K of
    # the K-padded f32 vector moves each way
    from repro.comm.codec import get_codec
    f32 = get_codec("f32")
    for L in (95, 96, 97):
        for K in (2, 3, 4):
            assert comm.XLABackend().wire_bytes(
                "reduce_scatter", f32, L, K) == \
                2 * (K - 1) * traffic.padded_len(L, K) * 4


# ---------------------------------------------------------------------------
# rule units on corpus-backed contexts (no compile needed)
# ---------------------------------------------------------------------------

def _ctx(graph, exchange, K=4, update_len=96, spec="test"):
    return acells.CellContext(
        cell=acells.Cell("cocoa", spec), trainer=None, round_fn=None,
        hlo_text="", graph=graph, K=K, exchange=exchange,
        update_len=update_len)


def _full_exchange(spec):
    from repro.core.distributed import ExchangeConfig
    return ExchangeConfig.parse(spec)


def test_rule_wire_dtype_flags_f32_escape():
    # an int8-claiming exchange over a graph that gathers f32 payload
    g = lift_hlo(corpus("tuple_layout.txt"))
    ctx = _ctx(g, _full_exchange("compressed:int8"))
    fs = RULES["wire-dtype"].check(ctx)
    assert fs and all(f.severity == "error" for f in fs)
    assert any("escaped" in f.message or "do not match" in f.message
               for f in fs)


def test_rule_wire_dtype_passes_on_matching_codec():
    g = lift_hlo(corpus("int8_gather.txt"))
    assert RULES["wire-dtype"].check(
        _ctx(g, _full_exchange("compressed:int8"))) == []
    # and the packed-int4 ring ships u8 on every hop
    assert RULES["wire-dtype"].check(
        _ctx(lift_hlo(corpus("ring_int4.txt")),
             _full_exchange("compressed:int4/ring"))) == []


def test_rule_ring_topology():
    g = lift_hlo(corpus("ring_int4.txt"))
    ctx = _ctx(g, _full_exchange("persistent/ring"))
    assert RULES["ring-topology"].check(ctx) == []
    # break one hop: a 2-cycle pair plus self-contained remainder is
    # not a single closed 4-ring
    broken = corpus("ring_int4.txt").replace(
        "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}",
        "source_target_pairs={{0,1},{1,0},{2,3},{3,2}}", 1)
    fs = RULES["ring-topology"].check(
        _ctx(lift_hlo(broken), _full_exchange("persistent/ring")))
    assert len(fs) == 1 and fs[0].severity == "error"


def test_rule_ring_topology_rejects_missing_rank():
    from repro.analysis.rules import _is_single_ring
    assert _is_single_ring(((0, 1), (1, 2), (2, 3), (3, 0)), 4)
    assert _is_single_ring(((1, 2), (2, 3), (3, 0), (0, 1)), 4)
    assert not _is_single_ring(((0, 1), (1, 0), (2, 3), (3, 2)), 4)
    assert not _is_single_ring(((0, 1), (1, 2), (2, 3)), 4)
    assert not _is_single_ring(((0, 1), (1, 2), (2, 0), (3, 3)), 4)
    assert not _is_single_ring(None, 4)


def test_rule_f32_intermediate_fires_on_decode():
    g = lift_hlo(corpus("int8_gather.txt"))
    fs = RULES["f32-intermediate"].check(
        _ctx(g, _full_exchange("compressed:int8")))
    # error severity since the fused decode+reduce kernels closed the
    # gather side — a reappearing stacked-f32 decode fails the gate
    assert len(fs) == 1 and fs[0].severity == "error"
    assert "broadcast_multiply_fusion" in fs[0].message
    # exact transports are exempt — f32 on the wire is their format
    assert RULES["f32-intermediate"].check(
        _ctx(g, _full_exchange("persistent"))) == []


def test_rule_bytes_match_reports_mismatch():
    g = lift_hlo(corpus("int8_gather.txt"))

    class FakeTrainer:
        def comm_bytes_per_round(self, t=None):
            return 12345
    ctx = _ctx(g, _full_exchange("compressed:int8"))
    ctx.trainer = FakeTrainer()
    fs = RULES["bytes-match"].check(ctx)
    assert len(fs) == 1 and "12345" in fs[0].message
    ctx.trainer.comm_bytes_per_round = lambda t=None: 2 * 4 * 100
    assert RULES["bytes-match"].check(ctx) == []


def test_registry_has_required_rules():
    required = {"bytes-match", "wire-dtype", "ring-topology",
                "membership-invariant", "f32-intermediate",
                "single-compile", "jit-module-array",
                "deprecated-spelling"}
    assert required <= set(RULES)
    assert all(RULES[r].severity == "error"
               for r in ("bytes-match", "wire-dtype", "ring-topology",
                         "membership-invariant", "single-compile",
                         "f32-intermediate"))
    assert max_severity([Finding("x", "warning", "c", "m"),
                         Finding("y", "error", "c", "m")]) == "error"
    assert max_severity([]) is None


def test_cell_selectors():
    assert len(acells.matrix_cells()) == 36
    assert len(acells.all_cells()) == 36 + len(acells.REGIME_CELLS) + \
        len(acells.BACKEND_CELLS) + len(acells.CODEC_CELLS)
    sel = acells.resolve_cells("cocoa=compressed:int8/stale")
    assert sel == (acells.Cell("cocoa", "compressed:int8/stale"),)
    with pytest.raises(ValueError):
        acells.resolve_cells("bogus=persistent")
    with pytest.raises(Exception):
        acells.resolve_cells("cocoa=not-a-transport")


# ---------------------------------------------------------------------------
# AST source lint
# ---------------------------------------------------------------------------

def _lint_str(tmp_path, src):
    p = tmp_path / "mod.py"
    p.write_text(src)
    return pylint_jax.lint_file(str(p), "mod.py")


def test_pylint_flags_jit_closed_module_array(tmp_path):
    fs = _lint_str(tmp_path, (
        "import jax\nimport jax.numpy as jnp\n"
        "W = jnp.zeros((4,))\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x + W\n"))
    assert [f.rule for f in fs] == ["jit-module-array"]
    assert fs[0].severity == "warning" and "'W'" in fs[0].message


def test_pylint_flags_wrapped_jit(tmp_path):
    fs = _lint_str(tmp_path, (
        "import jax, jax.numpy as jnp\n"
        "TABLE = jax.device_put(jnp.arange(8))\n"
        "def g(x):\n"
        "    return TABLE[x]\n"
        "g_fast = jax.jit(g)\n"))
    assert [f.rule for f in fs] == ["jit-module-array"]


def test_pylint_allows_arrays_passed_as_args(tmp_path):
    fs = _lint_str(tmp_path, (
        "import jax, jax.numpy as jnp\n"
        "W = jnp.zeros((4,))\n"
        "@jax.jit\n"
        "def f(x, W):\n"          # parameter shadows the module array
        "    return x + W\n"
        "def plain(x):\n"         # not jitted — closure is fine
        "    return x + W\n"))
    assert fs == []


def test_pylint_flags_deprecated_spellings(tmp_path):
    fs = _lint_str(tmp_path, (
        "from repro.core.distributed import get_scheme, resolve_exchange\n"
        "s = get_scheme('persistent')\n"
        "cfg = make_config(comm_scheme='persistent')\n"
        "ok = resolve_exchange(None, comm_scheme='persistent')\n"))
    assert [f.rule for f in fs] == ["deprecated-spelling"] * 2
    lines = sorted(f.cell for f in fs)
    assert lines == ["mod.py:2", "mod.py:3"]


def test_repo_source_is_lint_clean():
    src_root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    fs = pylint_jax.lint_source(os.path.abspath(src_root))
    assert fs == [], "\n".join(f"{f.cell}: {f.message}" for f in fs)
