"""The pluggable collective-backend layer (``repro.comm.collectives``):
registry + byte/latency cost models in-process, ring-vs-xla numerics in
one multi-device subprocess (faked host devices — the same pattern as
test_distributed.py; in-process tests see the single CPU device
conftest pins)."""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py: str, ndev: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# ---------------------------------------------------------------------------
# registry + protocol (in-process)
# ---------------------------------------------------------------------------
def test_backend_registry():
    from repro.comm.collectives import (BACKENDS, COLLECTIVE_BACKENDS,
                                        CollectiveBackend, get_backend)

    assert set(BACKENDS) == set(COLLECTIVE_BACKENDS) == {"xla", "ring"}
    for name in COLLECTIVE_BACKENDS:
        be = get_backend(name)
        assert be.name == name
        assert isinstance(be, CollectiveBackend)
    # None -> the default fused fabric; objects pass through
    assert get_backend(None).name == "xla"
    assert get_backend(BACKENDS["ring"]) is BACKENDS["ring"]
    with pytest.raises(ValueError, match="unknown collective backend"):
        get_backend("nccl")


def test_padded_len():
    from repro.comm.collectives import padded_len

    assert padded_len(8, 4) == 8
    assert padded_len(10, 4) == 12
    assert padded_len(1, 4) == 4
    assert padded_len(0, 4) == 0
    assert padded_len(7, 1) == 7


# ---------------------------------------------------------------------------
# byte models (in-process; HLO equality is pinned by bench_drivers)
# ---------------------------------------------------------------------------
def test_wire_bytes_per_backend():
    from repro.comm import get_codec
    from repro.comm.collectives import get_backend, padded_len

    K, L, S = 4, 96, 256        # S = total local-state elements
    f32, int8, int4 = (get_codec(c) for c in ("f32", "int8", "int4"))
    xla, ring = get_backend("xla"), get_backend("ring")

    # xla: the pre-backend formulas verbatim
    assert xla.wire_bytes("persistent", f32, L, K) == 2 * K * L * 4
    assert (xla.wire_bytes("spark_faithful", f32, L, K, local_state_len=S)
            == 2 * K * L * 4 + 2 * S * 4)
    assert (xla.wire_bytes("reduce_scatter", f32, L, K)
            == 2 * (K - 1) * padded_len(L, K) * 4)
    assert xla.wire_bytes("compressed", int8, L, K) == 2 * K * (L + 4)

    # ring: hop volume — K ranks each forward one part per hop
    assert (ring.wire_bytes("persistent", f32, L, K)
            == 2 * (K - 1) * padded_len(L, K) * 4)
    assert (ring.wire_bytes("reduce_scatter", f32, L, K)
            == 2 * (K - 1) * padded_len(L, K) * 4)
    assert (ring.wire_bytes("compressed", int4, L, K)
            == K * (K - 1) * int4.wire_bytes(L))
    assert (ring.wire_bytes("spark_faithful", f32, L, K, local_state_len=S)
            == K * (K - 1) * L * 4 + (K - 1) * S * 4)
    # padding charged on non-divisible lengths, both sum transports
    assert (ring.wire_bytes("persistent", f32, 10, K)
            == 2 * (K - 1) * 12 * 4)
    # membership-oblivious: K_live is ignored (like fused reduce_scatter)
    assert (ring.wire_bytes("persistent", f32, L, K, K_live=2)
            == ring.wire_bytes("persistent", f32, L, K))
    # a 1-rank ring moves nothing
    assert ring.wire_bytes("persistent", f32, L, 1) == 0
    assert ring.wire_bytes("compressed", int8, L, 1) == 0


def test_latency_hops():
    from repro.comm.collectives import get_backend

    xla, ring = get_backend("xla"), get_backend("ring")
    K = 4
    for transport in ("persistent", "spark_faithful", "compressed",
                      "reduce_scatter"):
        assert xla.latency_hops(transport, K) == 1
    # one gather ring for compressed, RS+AG (or two gather rings) else
    assert ring.latency_hops("compressed", K) == K - 1
    for transport in ("persistent", "spark_faithful", "reduce_scatter"):
        assert ring.latency_hops(transport, K) == 2 * (K - 1)
    assert ring.latency_hops("persistent", 1) == 0


def test_bytes_per_round_threads_backend():
    from repro.core.distributed import CommScheme

    sch = CommScheme.parse("compressed:int4")
    K, L = 4, 96
    assert (sch.bytes_per_round(L, K, backend="ring")
            == K * (K - 1) * sch.codec.wire_bytes(L))
    assert sch.bytes_per_round(L, K) == sch.bytes_per_round(
        L, K, backend="xla")


# ---------------------------------------------------------------------------
# ring-vs-xla numerics (one multi-device subprocess amortizing compiles)
# ---------------------------------------------------------------------------
def test_ring_matches_xla_all_transports():
    """Per transport on a real 4-device mesh: the ring all-reduce must
    equal the fused one — BIT-identical for the gather-then-sum-locally
    transports (``compressed``, ``spark_faithful``: the canonical-order
    ring gather feeds the identical local sum), allclose for the sum
    transports (``persistent``, ``reduce_scatter``: float reduction
    order differs). Padded + divisible + scalar lengths; plus the
    spark_faithful state round trip (exact identity on both fabrics)
    and the K=1 passthrough."""
    out = _run("""
import numpy as np, jax
from jax.sharding import PartitionSpec as P
from repro.utils import compat
from repro.core.distributed import CommScheme

mesh = jax.make_mesh((4,), ("w",))
K = 4
rng = np.random.default_rng(0)
BIT = ("spark_faithful", "compressed:f32", "compressed:int8",
       "compressed:int4")
for L in (8, 10, 1):
    x = rng.standard_normal((K, L)).astype(np.float32)
    for sname in BIT + ("persistent", "reduce_scatter"):
        sch = CommScheme.parse(sname)
        outs = {}
        for be in ("xla", "ring"):
            f = compat.shard_map(
                lambda u, _be=be: sch.all_reduce(u[0], "w", backend=_be)[None],
                mesh, in_specs=P("w"), out_specs=P("w"))
            outs[be] = np.asarray(jax.jit(f)(x))
        assert np.allclose(outs["xla"], outs["ring"], rtol=1e-6,
                           atol=1e-6), (L, sname)
        if sname in BIT:
            assert np.array_equal(outs["xla"], outs["ring"]), (L, sname)
st = rng.standard_normal((K, 6)).astype(np.float32)
sch = CommScheme.parse("spark_faithful")
for be in ("xla", "ring"):
    f = compat.shard_map(
        lambda s, _be=be: sch.roundtrip_local_state(s[0], "w",
                                                    backend=_be)[None],
        mesh, in_specs=P("w"), out_specs=P("w"))
    assert np.array_equal(np.asarray(jax.jit(f)(st)), st), be
m1 = jax.make_mesh((1,), ("w",), devices=jax.devices()[:1])
x1 = rng.standard_normal((1, 5)).astype(np.float32)
f1 = compat.shard_map(
    lambda u: CommScheme.parse("persistent").all_reduce(
        u[0], "w", backend="ring")[None],
    m1, in_specs=P("w"), out_specs=P("w"))
assert np.array_equal(np.asarray(jax.jit(f1)(x1)), x1)
print("RING_OK")
""")
    assert "RING_OK" in out


def test_ring_sharded_trainer_matches_virtual():
    """A CoCoA run on the sharded driver with the ring backend must
    track the (backend-oblivious) virtual driver exactly like the xla
    sharded leg does — the driver-parity contract is backend-invariant."""
    out = _run("""
import numpy as np
from repro.core import CoCoAConfig, CoCoATrainer
from repro.data import make_glm_data

A, b, _ = make_glm_data(m=48, n=96, density=0.3, seed=1)
ROUNDS = 5
runs = {}
for spec in ("persistent", "persistent/ring", "compressed:int8/ring"):
    tr = CoCoATrainer(CoCoAConfig(K=4, H=24, lam=1.0, solver="scd_ref",
                                  exchange=spec, seed=0), A, b)
    hist = tr.run_sharded(ROUNDS, record_every=1)
    runs[spec] = (hist.primal, tr.w_final.copy())
for spec, (primal, w) in runs.items():
    ref = CoCoATrainer(CoCoAConfig(K=4, H=24, lam=1.0, solver="scd_ref",
                                   exchange=spec, seed=0), A, b)
    hv = ref.run(ROUNDS, record_every=1)
    np.testing.assert_allclose(primal, hv.primal, rtol=1e-4, atol=1e-6,
                               err_msg=spec)
print("TRAJ_OK")
""")
    assert "TRAJ_OK" in out
