"""Multi-device tests (subprocess with faked host devices): shard_map
CoCoA driver, expert-parallel MoE, local-update rounds, and a dry-run
smoke on the production mesh.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py: str, ndev: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_cocoa_sharded_matches_virtual():
    _run("""
import numpy as np, jax
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
cfg = CoCoAConfig(K=8, H=64, seed=3)
t1 = CoCoATrainer(cfg, A, b); h1 = t1.run(rounds=20, record_every=20)
t2 = CoCoATrainer(cfg, A, b); h2 = t2.run_sharded(rounds=20, record_every=20)
# identical algorithm, identical rng -> identical trajectories
assert abs(h1.primal[-1] - h2.primal[-1]) / abs(h1.primal[-1]) < 1e-4, (h1.primal, h2.primal)
print("OK")
""")


def test_cocoa_spark_faithful_extra_collectives():
    _run("""
import numpy as np, jax, jax.random as jr
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
from repro.utils.hlo import parse_collectives
from repro.utils.compat import make_mesh
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
texts = {}
for scheme in ("persistent", "spark_faithful"):
    tr = CoCoATrainer(CoCoAConfig(K=8, H=32, comm_scheme=scheme), A, b)
    mesh = make_mesh((8,), ("workers",))
    rf = tr.build_sharded_round(mesh)
    alpha, w = tr.init_state()
    low = rf.jitted.lower(rf.split_keys(jr.key(0)), alpha, w, 1)
    texts[scheme] = parse_collectives(low.compile().as_text())
p, s = texts["persistent"], texts["spark_faithful"]
assert "all-gather" in s.by_kind and "all-gather" not in p.by_kind
assert s.total_operand_bytes > p.total_operand_bytes
print("OK")
""")


def test_driver_matrix_virtual_vs_sharded_all_algorithms():
    """The unified layer's contract: for every algorithm x comm scheme,
    the virtual (vmap) and sharded (shard_map) drivers follow the same
    trajectory (identical per-worker RNG; only reduction mechanics
    differ)."""
    _run("""
import numpy as np
from repro.data import make_glm_data
from repro.core import (CoCoAConfig, CoCoATrainer, MinibatchSCD,
                        MinibatchSGD, SGDConfig, COMM_SCHEMES)
A, b, _ = make_glm_data(m=96, n=256, density=0.2, zipf_a=1.1, seed=42)
def make(algo, scheme):
    if algo == "minibatch_sgd":
        return MinibatchSGD(SGDConfig(batch_frac=1.0, step_size=0.1,
                                      lam=1.0, K=4, seed=0,
                                      comm_scheme=scheme), A, b)
    cfg = CoCoAConfig(K=4, H=64, comm_scheme=scheme, seed=0)
    return (MinibatchSCD if algo == "minibatch_scd" else CoCoATrainer)(cfg, A, b)
for algo in ("cocoa", "minibatch_scd", "minibatch_sgd"):
    for scheme in COMM_SCHEMES:
        tv = make(algo, scheme)
        hv = (tv.run_workers(12, record_every=12)
              if algo == "minibatch_sgd" else tv.run(12, record_every=12))
        ts = make(algo, scheme)
        hs = ts.run_sharded(12, record_every=12)
        rel = abs(hv.primal[-1] - hs.primal[-1]) / abs(hv.primal[-1])
        assert rel < 1e-4, (algo, scheme, hv.primal, hs.primal)
print("OK")
""", ndev=4, timeout=560)


def test_sharded_sgd_allreduce_n_vector_cocoa_m_vector():
    """Paper §5.4: mini-batch SGD all-reduces the n-dim gradient while
    CoCoA all-reduces the m-dim Delta v — more traffic whenever n > m,
    and it must be visible in the HLO."""
    _run("""
import re, jax, jax.random as jr
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer, MinibatchSGD, SGDConfig
from repro.utils.compat import make_mesh
m, n = 96, 256
A, b, _ = make_glm_data(m=m, n=n, density=0.2, seed=1)
mesh = make_mesh((4,), ("workers",))
def hlo(tr):
    rf = tr.build_sharded_round(mesh)
    local, shared = tr.init_state()
    return rf.jitted.lower(rf.split_keys(jr.key(0)),
                           local, shared, 1).compile().as_text()
coc = hlo(CoCoATrainer(CoCoAConfig(K=4, H=32), A, b))
sgd = hlo(MinibatchSGD(SGDConfig(K=4, step_size=0.1), A, b))
assert re.search(rf"f32\\[{m}\\]\\S* all-reduce", coc), "m-vector all-reduce missing"
assert not re.search(rf"f32\\[{n}\\]\\S* all-reduce", coc), "CoCoA must not move an n-vector"
assert re.search(rf"f32\\[{n}\\]\\S* all-reduce", sgd), "n-vector all-reduce missing"
assert not re.search(rf"f32\\[{m}\\]\\S* all-reduce", sgd), "SGD must not move an m-vector"
print("OK")
""", ndev=4)


def test_compressed_quantizer_bit_identical_across_drivers():
    """Both drivers call the ONE shared quantization helper, so the
    dequantized updates — and their aggregate — are bit-identical
    between the virtual and sharded paths."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.distributed import (get_scheme, quantize_update,
                                    dequantize_update)
from repro.utils.compat import make_mesh, shard_map
K, m = 4, 96
dv = jax.random.normal(jax.random.key(7), (K, m), jnp.float32)
dv = dv * (10.0 ** jnp.arange(-2, K - 2, dtype=jnp.float32))[:, None]
mesh = make_mesh((K,), ("workers",))
# per-worker dequantized updates: vmapped helper vs per-shard helper
q, s = jax.vmap(quantize_update)(dv)
virt = dequantize_update(q, s[:, None])
f = shard_map(lambda d: dequantize_update(*quantize_update(d[0]))[None],
              mesh, in_specs=P("workers"), out_specs=P("workers"))
shrd = jax.jit(f)(dv)
assert np.array_equal(np.asarray(virt), np.asarray(shrd)), "per-worker drift"
# the aggregated update the round actually applies
scheme = get_scheme("compressed")
agg_v = scheme.all_reduce_stacked(dv)
g = shard_map(lambda d: scheme.all_reduce(d[0], "workers"), mesh,
              in_specs=P("workers"), out_specs=P(None))
agg_s = jax.jit(g)(dv)
assert np.array_equal(np.asarray(agg_v), np.asarray(agg_s)), "aggregate drift"
print("OK")
""", ndev=4)


def test_moe_sharded_matches_global():
    _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import layers as L
cfg = get_config("deepseek-v3-671b").reduced()
from repro.utils.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
p = L.init_moe(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32) * 0.1
L.set_partitioning(dp=("data",), tp="model", mesh=mesh)
with mesh:
    y1, _ = jax.jit(lambda p, x: L.moe_apply(p, cfg, x))(p, x)
L.set_partitioning()
y2, _ = L.moe_apply(p, cfg, x)
d = float(jnp.max(jnp.abs(y1 - y2)))
assert d < 1e-5, d
print("OK")
""")


def test_local_updates_H1_sgd_equals_sync_dp():
    """With plain SGD, H=1 local updates == synchronous data parallelism
    (gradient averaging) — the paper's knob reduces to the baseline."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim import LocalUpdatesConfig, local_updates_round
from repro.utils.compat import make_mesh
mesh = make_mesh((4,), ("data",))
lr = 0.1
def loss(w, b):
    x, y = b
    return jnp.mean((x @ w - y) ** 2)
def sgd_step(w, o, b):
    g = jax.grad(loss)(w, b)
    return w - lr * g, o, {}
rng = np.random.default_rng(0)
X = jnp.asarray(rng.standard_normal((8, 4, 3)), jnp.float32)  # 8 shards*... (4 per shard? -> (4 shards,2,... )
X = jnp.asarray(rng.standard_normal((4, 1, 6, 3)), jnp.float32)  # (shards, H=1, batch, feat)
Y = jnp.asarray(rng.standard_normal((4, 1, 6)), jnp.float32)
w0 = jnp.zeros((3,))
# reference: one sync step on the full data
g_full = jax.grad(loss)(w0, (X.reshape(-1, 3), Y.reshape(-1)))
w_ref = w0 - lr * g_full
# local-updates H=1 via shard_map over data
def round_fn(w, Xs, Ys):
    def body(Xl, Yl, w):
        cfg = LocalUpdatesConfig(H=1)
        w2, _, _ = local_updates_round(sgd_step, w, {}, (Xl[0], Yl[0]), cfg, "data")
        return w2
    from repro.utils.compat import shard_map
    return shard_map(body, mesh,
        in_specs=(P("data"), P("data"), P(None)), out_specs=P(None))(Xs, Ys, w)
w_lu = jax.jit(round_fn)(w0, X, Y)
assert float(jnp.max(jnp.abs(w_lu - w_ref))) < 1e-6, (w_lu, w_ref)
print("OK")
""")


@pytest.mark.slow
def test_dryrun_production_mesh_smoke():
    """The real deliverable-(e) path: tinyllama decode on the 16x16 and
    2x16x16 meshes must lower + compile in a 512-device subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
         "--both-meshes", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=560, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "all dry-runs OK" in out.stdout


def test_cocoa_compressed_int8_collective():
    """The compressed scheme's collective moves int8, not f32."""
    _run("""
import numpy as np, jax, jax.random as jr, re
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
tr = CoCoATrainer(CoCoAConfig(K=8, H=32, comm_scheme="compressed"), A, b)
from repro.utils.compat import make_mesh
mesh = make_mesh((8,), ("workers",))
rf = tr.build_sharded_round(mesh)
alpha, w = tr.init_state()
txt = rf.jitted.lower(rf.split_keys(jr.key(0)), alpha, w, 1).compile().as_text()
assert re.search(r"s8\\[[0-9,]+\\][^ ]* all-gather", txt), "int8 all-gather missing"
h = tr.run_sharded(rounds=25, record_every=25)
assert h.subopt[-1] < 5e-2, h.subopt
print("OK")
""")
