"""Multi-device tests (subprocess with faked host devices): shard_map
CoCoA driver, the sync/stale exchange-mode contract (all staleness
bounds k), elastic worker membership, expert-parallel MoE,
local-update rounds, and a dry-run smoke on the production mesh —
plus the in-process codec round-trip property test over ALL wire codecs
(f32 / int8 / packed int4; hypothesis when installed, a deterministic
seed battery otherwise; NOT a module-wide importorskip, so the rest of
this file always runs).
"""
import functools
import os
import subprocess
import sys

import numpy as np
import pytest

# hypothesis is a dev extra (CI installs it via .[dev]); without it the
# property test below degrades to a fixed battery of generated examples
# instead of skipping, so the quantizer contract is always exercised
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py: str, ndev: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# ---------------------------------------------------------------------------
# codec round-trip property test, ALL codecs (in-process; hypothesis
# optional)
# ---------------------------------------------------------------------------
CODEC_NAMES = ("f32", "int8", "int4", "int2", "topk(r=0.125)",
               "ef:int4", "ef:int2", "ef:topk(r=0.125)")


@functools.cache
def _codec_paths(codec_name: str):
    """The execution paths of one codec's encode/decode round-trip, all
    JITTED (as the drivers run them; jit re-specializes per input shape
    on its own): the vmap stacked path, the per-shard shard_map path on
    a 1-device ``workers`` axis (the 4-device variant is covered by
    ``test_compressed_quantizer_bit_identical_across_drivers`` below),
    and the aggregate each mode applies. Eager execution is
    deliberately NOT a reference here — XLA may lower the division by
    the absmax scale differently than op-by-op dispatch, and the
    drivers' contract is jitted-vs-jitted."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.comm import get_codec
    from repro.core.distributed import CommScheme
    from repro.utils import compat

    codec = get_codec(codec_name)

    @jax.jit
    def vmap_path(d):
        parts = jax.vmap(codec.encode)(d)
        return codec.decode_stacked(parts, d.shape[1])

    mesh = compat.make_mesh((1,), ("workers",))
    shard_path = jax.jit(compat.shard_map(
        lambda d: codec.decode(codec.encode(d[0]), d.shape[-1])[None],
        mesh, in_specs=P("workers"), out_specs=P("workers")))
    agg_path = jax.jit(
        CommScheme.parse(f"compressed:{codec_name}").all_reduce_stacked)
    # the aggregate reference restates each codec's reduction contract:
    # quantizing codecs accumulate SEQUENTIALLY in canonical worker
    # order behind the _no_fma guard (the fused decode+reduce oracle in
    # repro.kernels.dequant), everything else is the plain jnp.sum
    if codec_name.removeprefix("ef:") in ("int8", "int4", "int2"):
        from repro.kernels.dequant import _no_fma

        def _seq_sum(rows):
            acc = _no_fma(rows[0])
            for k in range(1, rows.shape[0]):
                acc = acc + _no_fma(rows[k])
            return acc
        sum_path = jax.jit(_seq_sum)
    else:
        sum_path = jax.jit(lambda rows: jax.numpy.sum(rows, axis=0))
    scales_path = jax.jit(lambda d: jax.vmap(codec.encode)(d)[-1])
    return vmap_path, shard_path, agg_path, sum_path, scales_path


def _roundtrip_bound(codec_name: str, scales: np.ndarray) -> np.ndarray:
    """Per-row elementwise error bound of ``decode(encode(x))``.

    * ``f32``  — the identity: exact.
    * ``int8`` — scale/2: absmax scaling puts every entry inside
      [-127, 127]*scale, so clipping never bites and the only error is
      round-to-nearest.
    * ``int4`` — scale/2 likewise (scale = absmax/7.5, the 15-level
      grid over [-absmax, absmax]): the bound equals absmax/15, which
      is ~8.5x the int8 codec's scale — the price of packing two
      elements per byte.
    * ``int2`` — scale/2 again (scale = absmax * 2/3, the ternary
      grid): the same clip-at-the-extreme argument as int4.
    * ``topk`` — kept entries decode exactly; every dropped entry
      satisfies |x| <= threshold (the k-th largest magnitude, the
      codec's "scale" wire part), so the threshold IS the bound.
    * ``ef:<base>`` — the stateless entry point encodes with a zero
      residual, i.e. exactly the base codec: the base codec's bound.

    The f32 divide/multiply round-trip gets a 1-ulp-ish allowance.
    """
    codec_name = codec_name.removeprefix("ef:")
    if codec_name == "f32":
        return np.zeros_like(scales)[:, None]
    if codec_name.startswith("topk"):
        return scales[:, None] * (1 + 1e-5) + 1e-30
    return 0.5 * scales[:, None] * (1 + 1e-5) + 1e-30


def _check_codec_roundtrip(codec_name: str, dv_np: np.ndarray):
    """The codec contract on one (K, L) update stack: elementwise
    round-trip error bounded by the codec's grid (see
    ``_roundtrip_bound``), zero rows decoding to exact zeros, and the
    vmap path bit-identical to the per-shard shard_map path (both for
    the per-worker vectors and for the aggregate the round applies)."""
    import jax.numpy as jnp

    dv = jnp.asarray(dv_np, jnp.float32)
    (vmap_path, shard_path, agg_path, sum_path,
     scales_path) = _codec_paths(codec_name)
    deq = vmap_path(dv)
    s = (np.asarray(scales_path(dv)) if codec_name != "f32"
         else np.zeros(dv.shape[0], np.float32))
    err = np.abs(np.asarray(deq) - np.asarray(dv))
    bound = _roundtrip_bound(codec_name, s)
    assert (err <= bound).all(), (
        f"{codec_name}: round-trip error {err.max()} exceeds the grid "
        f"bound (worst scale {s.max()})")
    # an all-zero worker row must decode to EXACT zeros — the explicit
    # guarantee of every codec (guarded scale, symmetric grid with 0)
    zero_rows = ~np.any(dv_np, axis=1)
    assert (np.asarray(deq)[zero_rows] == 0).all(), (
        f"{codec_name}: zero update decoded to nonzero values")
    # bit-identity with the shard_map path, per worker row
    shard_rows = [shard_path(row[None]) for row in dv]
    for k, row in enumerate(shard_rows):
        assert np.array_equal(np.asarray(row[0]), np.asarray(deq[k])), \
            f"{codec_name} worker {k}: vmap and shard_map dequants " \
            f"differ bitwise"
    # ... and for the aggregate the compressed exchange applies
    agg_v = agg_path(dv)
    agg_s = sum_path(jnp.concatenate(shard_rows, axis=0))
    assert np.array_equal(np.asarray(agg_v), np.asarray(agg_s)), \
        f"{codec_name}: aggregate drift between vmap and shard_map paths"


def _check_all_codecs(dv_np: np.ndarray):
    for codec_name in CODEC_NAMES:
        _check_codec_roundtrip(codec_name, dv_np)


def _random_update_stack(seed: int) -> np.ndarray:
    """A (4, 64) f32 update stack with per-worker magnitudes swept over
    ~40 decades (denormal-adjacent through 1e20), plus exact zeros."""
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((4, 64)).astype(np.float32)
    exps = rng.uniform(-20.0, 20.0, size=(4, 1)).astype(np.float32)
    dv = base * (10.0 ** exps)
    if seed % 3 == 0:
        dv[seed % 4] = 0.0          # an all-zero worker update
    if seed % 4 == 0:
        dv[0, seed % 64] = 0.0      # sparse zeros inside a row
    return dv.astype(np.float32)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_codec_roundtrip_property(seed):
        _check_all_codecs(_random_update_stack(seed))
else:
    @pytest.mark.parametrize("seed", range(30))
    def test_codec_roundtrip_property(seed):
        _check_all_codecs(_random_update_stack(seed))


def test_codec_roundtrip_edge_values():
    """Exact edge cases the random sweep may miss: all-zero stacks, a
    single huge entry, values straddling the int8 clip boundary, and
    single-element updates (odd length: the int4 packer's padded
    nibble)."""
    _check_all_codecs(np.zeros((4, 64), np.float32))
    spike = np.zeros((4, 64), np.float32)
    spike[1, 3] = 3e38
    _check_all_codecs(spike)
    ramp = np.tile(np.linspace(-1.0, 1.0, 64, dtype=np.float32), (4, 1))
    _check_all_codecs(ramp * 127.49)
    _check_all_codecs(np.asarray([[2.5], [-1e-8], [0.0], [3e38]],
                                 np.float32))
    _check_all_codecs(np.ones((1, 1), np.float32))


def test_int2_pack_layout_and_wire_bytes():
    """The packed int2 wire format: ceil(L/4) uint8 payload under
    split-quarter pairing (element i shares a byte with i + q, i + 2q,
    i + 3q for q = ceil(L/4), biased codes q+2 in two-bit lanes), plus
    the 4-byte scale."""
    import jax
    import jax.numpy as jnp

    from repro.comm import get_codec

    codec = get_codec("int2")
    for L in (1, 2, 3, 7, 64, 97):
        dv = jnp.asarray(np.linspace(-1, 1, L), jnp.float32)
        packed, scale = jax.jit(codec.encode_ref)(dv)
        quarter = -(-L // 4)
        assert packed.shape == (quarter,) and packed.dtype == jnp.uint8
        assert codec.wire_bytes(L) == quarter + 4
        q = np.round(np.asarray(dv) / float(scale)).clip(-1, 1).astype(int)
        q = np.concatenate([q, np.zeros(4 * quarter - L, int)]) + 2
        rows = q.reshape(4, quarter)
        expect = (rows[0] | (rows[1] << 2) | (rows[2] << 4)
                  | (rows[3] << 6))
        assert (np.asarray(packed) == expect).all(), L


def test_topk_wire_format_and_threshold():
    """topk's wire tuple: exact f32 values + int32 indices of the k
    largest-magnitude entries, threshold (the k-th magnitude) last.
    Decode scatters the values and drops nothing above the threshold
    (on honest wire data the threshold mask is the identity)."""
    import jax.numpy as jnp

    from repro.comm import get_codec

    codec = get_codec("topk(r=0.125)")
    dv = jnp.asarray([0.0, -5.0, 1.0, 0.25, 3.0, -0.5, 0.0, 2.0,
                      -1.5, 0.125, 0.0, 4.0, -0.25, 0.75, 0.0, -3.5],
                     jnp.float32)
    values, idx, thr = codec.encode(dv)       # k = ceil(0.125*16) = 2
    assert values.shape == (2,) and idx.dtype == jnp.int32
    assert set(np.asarray(idx).tolist()) == {1, 11}   # -5.0 and 4.0
    assert float(thr) == 4.0
    dec = codec.decode((values, idx, thr), 16)
    expect = np.zeros(16, np.float32)
    expect[1], expect[11] = -5.0, 4.0
    assert np.array_equal(np.asarray(dec), expect)
    # r is clamped so k never exceeds L
    assert get_codec("topk(r=1)").wire_bytes(3) == 8 * 3 + 4


def test_int4_pack_layout_and_wire_bytes():
    """The packed int4 wire format: ceil(L/2) uint8 payload under
    split-half pairing (element i shares a byte with element
    i + ceil(L/2)), plus the 4-byte scale — the formula the byte model
    charges."""
    import jax
    import jax.numpy as jnp

    from repro.comm import get_codec

    codec = get_codec("int4")
    for L in (1, 2, 7, 64, 97):
        dv = jnp.asarray(np.linspace(-1, 1, L), jnp.float32)
        packed, scale = jax.jit(codec.encode_ref)(dv)
        assert packed.shape == ((L + 1) // 2,) and packed.dtype == jnp.uint8
        assert codec.wire_bytes(L) == (L + 1) // 2 + 4
        half = (L + 1) // 2
        q = np.round(np.asarray(dv) / float(scale)).clip(-7, 7).astype(int)
        q = np.concatenate([q, np.zeros(2 * half - L, int)])
        expect = (q[:half] + 8) | ((q[half:] + 8) << 4)
        assert (np.asarray(packed) == expect).all(), L


def test_quantize_pack_kernel_bit_identical_to_oracle():
    """The fused Pallas quantize+pack kernel (interpret mode off-TPU)
    must be BIT-identical to the jitted jnp oracle — payload and scale
    — for both codecs, across lengths exercising lane padding and the
    odd-length int4 tail."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import (quantize_pack_int2, quantize_pack_int2_ref,
                               quantize_pack_int4, quantize_pack_int4_ref,
                               quantize_pack_int8, quantize_pack_int8_ref)

    pairs = ((jax.jit(quantize_pack_int8_ref), quantize_pack_int8),
             (jax.jit(quantize_pack_int4_ref), quantize_pack_int4),
             (jax.jit(quantize_pack_int2_ref), quantize_pack_int2))
    for L in (1, 2, 7, 96, 128, 257):
        for seed in range(3):
            r = np.random.default_rng(1000 * L + seed)
            dv = jnp.asarray(
                r.standard_normal(L) * 10.0 ** r.uniform(-8, 8),
                jnp.float32)
            for ref_fn, ker_fn in pairs:
                p_r, s_r = ref_fn(dv)
                p_k, s_k = ker_fn(dv)
                assert np.array_equal(np.asarray(p_r), np.asarray(p_k)), (
                    L, seed, ker_fn.__name__)
                assert float(s_r) == float(s_k), (L, seed)
        z = jnp.zeros((L,), jnp.float32)
        for ref_fn, ker_fn in pairs:
            p_r, s_r = ref_fn(z)
            p_k, s_k = ker_fn(z)
            assert np.array_equal(np.asarray(p_r), np.asarray(p_k))
            assert float(s_r) == float(s_k) == 1.0  # the zero guard


def test_compressed_int8_bit_identical_to_legacy_quantizer():
    """Regression pin on the codec refactor: ``compressed:int8`` (and
    its bare ``compressed`` alias) must aggregate BIT-identically to
    the pre-codec quantizer (``scale = absmax/127 + 1e-30`` inline in
    core/distributed.py) for any nonzero input — the refactor moved
    the int8 path, it must not have changed it. The fused decode+reduce
    rework replaced the legacy ``jnp.sum`` over the stacked f32 decode
    with SEQUENTIAL accumulation in canonical worker order (the
    ``decode_stacked_ref`` oracle contract), so the legacy reference is
    restated in that order here — same quantizer, same values, pinned
    reduction sequence."""
    import jax
    import jax.numpy as jnp

    from repro.core.distributed import CommScheme
    from repro.kernels.dequant import _no_fma

    @jax.jit
    def legacy_stacked(updates):
        def q1(dv):
            scale = jnp.max(jnp.abs(dv)) / 127.0 + 1e-30
            q = jnp.clip(jnp.round(dv / scale), -127, 127).astype(jnp.int8)
            return q, scale
        q, scale = jax.vmap(q1)(updates)
        stack = q.astype(jnp.float32) * scale[:, None]
        acc = _no_fma(stack[0])
        for k in range(1, stack.shape[0]):
            acc = acc + _no_fma(stack[k])
        return acc

    aliased = jax.jit(CommScheme.parse("compressed").all_reduce_stacked)
    named = jax.jit(CommScheme.parse("compressed:int8").all_reduce_stacked)
    for seed in range(20):
        dv = jnp.asarray(_random_update_stack(seed), jnp.float32)
        want = np.asarray(legacy_stacked(dv))
        assert np.array_equal(want, np.asarray(aliased(dv))), seed
        assert np.array_equal(want, np.asarray(named(dv))), seed


def test_compressed_alias_trajectory_bit_identical():
    """End-to-end regression: a CoCoA run under the bare ``compressed``
    scheme and under the explicit ``compressed:int8`` spelling must
    produce bit-identical iterates (the alias is the same codec object,
    not a second implementation)."""
    from repro.core import CoCoAConfig, CoCoATrainer
    from repro.data import make_glm_data

    A, b, _ = make_glm_data(m=64, n=128, density=0.3, seed=3)
    finals = {}
    for scheme in ("compressed", "compressed:int8"):
        tr = CoCoATrainer(CoCoAConfig(K=4, H=32, seed=0,
                                      exchange=scheme), A, b)
        tr.run(6, record_every=6)
        finals[scheme] = (tr.alpha_final, tr.w_final)
    assert np.array_equal(finals["compressed"][0],
                          finals["compressed:int8"][0])
    assert np.array_equal(finals["compressed"][1],
                          finals["compressed:int8"][1])


def test_cocoa_sharded_matches_virtual():
    _run("""
import numpy as np, jax
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
cfg = CoCoAConfig(K=8, H=64, seed=3)
t1 = CoCoATrainer(cfg, A, b); h1 = t1.run(rounds=20, record_every=20)
t2 = CoCoATrainer(cfg, A, b); h2 = t2.run_sharded(rounds=20, record_every=20)
# identical algorithm, identical rng -> identical trajectories
assert abs(h1.primal[-1] - h2.primal[-1]) / abs(h1.primal[-1]) < 1e-4, (h1.primal, h2.primal)
print("OK")
""")


def test_cocoa_spark_faithful_extra_collectives():
    _run("""
import numpy as np, jax, jax.random as jr
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
from repro.utils.hlo import parse_collectives
from repro.utils.compat import make_mesh
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
texts = {}
for scheme in ("persistent", "spark_faithful"):
    tr = CoCoATrainer(CoCoAConfig(K=8, H=32, exchange=scheme), A, b)
    mesh = make_mesh((8,), ("workers",))
    rf = tr.build_sharded_round(mesh)
    alpha, w = tr.init_state()
    low = rf.jitted.lower(rf.split_keys(jr.key(0)), alpha, w, 1)
    texts[scheme] = parse_collectives(low.compile().as_text())
p, s = texts["persistent"], texts["spark_faithful"]
assert "all-gather" in s.by_kind and "all-gather" not in p.by_kind
assert s.total_operand_bytes > p.total_operand_bytes
print("OK")
""")


def test_driver_matrix_virtual_vs_sharded_all_algorithms():
    """The unified layer's contract: for every algorithm x comm scheme,
    the virtual (vmap) and sharded (shard_map) drivers follow the same
    trajectory (identical per-worker RNG; only reduction mechanics
    differ)."""
    _run("""
import numpy as np
from repro.data import make_glm_data
from repro.core import (CoCoAConfig, CoCoATrainer, MinibatchSCD,
                        MinibatchSGD, SGDConfig, COMM_SCHEMES)
A, b, _ = make_glm_data(m=96, n=256, density=0.2, zipf_a=1.1, seed=42)
def make(algo, scheme):
    if algo == "minibatch_sgd":
        return MinibatchSGD(SGDConfig(batch_frac=1.0, step_size=0.1,
                                      lam=1.0, K=4, seed=0,
                                      exchange=scheme), A, b)
    cfg = CoCoAConfig(K=4, H=64, exchange=scheme, seed=0)
    return (MinibatchSCD if algo == "minibatch_scd" else CoCoATrainer)(cfg, A, b)
for algo in ("cocoa", "minibatch_scd", "minibatch_sgd"):
    for scheme in COMM_SCHEMES:
        tv = make(algo, scheme)
        hv = (tv.run_workers(12, record_every=12)
              if algo == "minibatch_sgd" else tv.run(12, record_every=12))
        ts = make(algo, scheme)
        hs = ts.run_sharded(12, record_every=12)
        rel = abs(hv.primal[-1] - hs.primal[-1]) / abs(hv.primal[-1])
        assert rel < 1e-4, (algo, scheme, hv.primal, hs.primal)
print("OK")
""", ndev=4, timeout=560)


def test_single_round_stale_equals_sync_all_algorithms_both_drivers():
    """Regression pin on the delayed apply's off-by-one: with exactly
    one round there is nothing to be stale about — the flushed `stale`
    iterate must be IDENTICAL to the `sync` iterate for all 3 algorithms
    on both drivers, for EVERY staleness bound k (same per-worker RNG,
    same aggregate, applied once either way; the flush absorbs however
    many slots are pending). A stale run that drops or double-applies a
    pending aggregate fails this immediately. Multi-round trajectories
    must then genuinely diverge (the knob does something), and deeper k
    must diverge from k=1 too."""
    _run("""
import numpy as np
from repro.data import make_glm_data
from repro.core import (CoCoAConfig, CoCoATrainer, MinibatchSCD,
                        MinibatchSGD, SGDConfig)
A, b, _ = make_glm_data(m=96, n=256, density=0.2, zipf_a=1.1, seed=42)
def make(algo, mode):
    if algo == "minibatch_sgd":
        return MinibatchSGD(SGDConfig(batch_frac=1.0, step_size=0.1,
                                      lam=1.0, K=4, seed=0,
                                      exchange=mode), A, b)
    cfg = CoCoAConfig(K=4, H=64, seed=0, exchange=mode)
    return (MinibatchSCD if algo == "minibatch_scd" else CoCoATrainer)(cfg, A, b)
for algo in ("cocoa", "minibatch_scd", "minibatch_sgd"):
    for driver in ("virtual", "sharded"):
        def run1(tr, rounds=1):
            if driver == "sharded":
                return tr.run_sharded(rounds, record_every=1)
            return (tr.run_workers(rounds, record_every=1)
                    if algo == "minibatch_sgd"
                    else tr.run(rounds, record_every=1))
        ts = make(algo, "sync"); run1(ts)
        for stale in ("stale", "stale:k=2", "stale:k=3"):
            tt = make(algo, stale); run1(tt)
            assert np.array_equal(ts.alpha_final, tt.alpha_final), (
                algo, driver, stale, "alpha drift after 1 round")
            if algo != "minibatch_sgd":  # CoCoA-family: shared residual
                assert np.array_equal(ts.w_final, tt.w_final), (
                    algo, driver, stale, "w drift after 1 round")
    # with >1 round the delayed apply must actually change the
    # trajectory (otherwise the knob is a no-op), and k=2 must be a
    # genuinely deeper delay than k=1
    finals = {}
    for mode in ("sync", "stale", "stale:k=2"):
        tr = make(algo, mode)
        (tr.run_workers(5, record_every=5) if algo == "minibatch_sgd"
         else tr.run(5, record_every=5))
        finals[mode] = np.asarray(tr.alpha_final)
    assert not np.array_equal(finals["sync"], finals["stale"]), (
        algo, "stale trajectory identical to sync after 5 rounds")
    assert not np.array_equal(finals["stale"], finals["stale:k=2"]), (
        algo, "stale:k=2 trajectory identical to k=1 after 5 rounds")
print("OK")
""", ndev=4, timeout=560)


def test_stale_driver_agreement_and_same_collectives():
    """The exchange-mode contract on the sharded driver: under `stale`
    (any bound k) the virtual and sharded drivers still follow the same
    trajectory for every comm scheme, and staleness never changes what
    the collectives move — the optimized HLO's collective traffic is
    byte-for-byte the same as the sync round's."""
    _run("""
import numpy as np, jax.random as jr
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer, COMM_SCHEMES
from repro.utils.hlo import parse_collectives
from repro.utils.compat import make_mesh
A, b, _ = make_glm_data(m=96, n=256, density=0.2, zipf_a=1.1, seed=42)
mesh = make_mesh((4,), ("workers",))
def traffic(tr):
    rf = tr.build_sharded_round(mesh)
    local, shared = tr.init_state()
    txt = rf.jitted.lower(rf.split_keys(jr.key(0)),
                          local, shared, 1).compile().as_text()
    s = parse_collectives(txt)
    return {k: v[1] for k, v in s.by_kind.items()}
for scheme in COMM_SCHEMES:
    for stale in ("stale", "stale:k=2"):
        spec = scheme + "/" + stale
        tv = CoCoATrainer(CoCoAConfig(K=4, H=64, seed=0, exchange=spec),
                          A, b)
        hv = tv.run(8, record_every=8)
        ts = CoCoATrainer(CoCoAConfig(K=4, H=64, seed=0, exchange=spec),
                          A, b)
        hs = ts.run_sharded(8, record_every=8)
        rel = abs(hv.primal[-1] - hs.primal[-1]) / abs(hv.primal[-1])
        assert rel < 1e-4, (spec, hv.primal, hs.primal)
        t_sync = traffic(CoCoATrainer(CoCoAConfig(K=4, H=64, seed=0,
                                                  exchange=scheme), A, b))
        t_stale = traffic(CoCoATrainer(CoCoAConfig(K=4, H=64, seed=0,
                                                   exchange=spec), A, b))
        assert t_sync == t_stale, (spec, t_sync, t_stale)
print("OK")
""", ndev=4, timeout=560)


def test_elastic_membership_virtual_vs_sharded():
    """The elastic-membership contract: with workers dropping and
    rejoining at configured rounds the virtual and sharded drivers
    still follow the same trajectory (the live mask is applied
    identically inside both), including when composed with a staleness
    bound and a quantizing codec — and membership adds NO collectives
    to the compiled round (one compile serves every round; liveness is
    an elementwise mask, so the HLO traffic matches the always-live
    program byte-for-byte)."""
    _run("""
import dataclasses
import numpy as np, jax.random as jr
from repro.data import make_glm_data
from repro.core import (CoCoAConfig, CoCoATrainer, ExchangeConfig,
                        MembershipSchedule, MinibatchSGD, SGDConfig)
from repro.utils.hlo import parse_collectives
from repro.utils.compat import make_mesh
A, b, _ = make_glm_data(m=96, n=256, density=0.2, zipf_a=1.1, seed=42)
mesh = make_mesh((4,), ("workers",))
def make(algo, spec):
    if algo == "minibatch_sgd":
        return MinibatchSGD(SGDConfig(batch_frac=1.0, step_size=0.1,
                                      lam=1.0, K=4, seed=0,
                                      exchange=spec), A, b)
    return CoCoATrainer(CoCoAConfig(K=4, H=64, seed=0, exchange=spec),
                        A, b)
def traffic(tr):
    rf = tr.build_sharded_round(mesh)
    local, shared = tr.init_state()
    txt = rf.jitted.lower(rf.split_keys(jr.key(0)),
                          local, shared, 1).compile().as_text()
    return {k: v[1] for k, v in parse_collectives(txt).by_kind.items()}
CASES = (("cocoa", "persistent/drop:1@2-4"),
         ("cocoa", "compressed:int8/stale:k=2/drop:0@1-2"),
         ("minibatch_sgd", "persistent/drop:2@3"),
         ("minibatch_sgd", "compressed:int4/drop:1@2-4"))
for algo, spec in CASES:
    tv = make(algo, spec)
    hv = (tv.run_workers(8, record_every=8) if algo == "minibatch_sgd"
          else tv.run(8, record_every=8))
    ts = make(algo, spec)
    hs = ts.run_sharded(8, record_every=8)
    rel = abs(hv.primal[-1] - hs.primal[-1]) / abs(hv.primal[-1])
    assert rel < 1e-4, (algo, spec, hv.primal, hs.primal)
    # the drop must actually bite: trajectory differs from always-live
    base_spec = dataclasses.replace(ExchangeConfig.parse(spec),
                                    membership=MembershipSchedule())
    always = make(algo, base_spec)
    (always.run_workers(8, record_every=8) if algo == "minibatch_sgd"
     else always.run(8, record_every=8))
    assert not np.array_equal(np.asarray(tv.alpha_final),
                              np.asarray(always.alpha_final)), (algo, spec)
    # ... without adding or resizing any collective
    assert traffic(make(algo, spec)) == traffic(always), (algo, spec)
print("OK")
""", ndev=4, timeout=560)


def test_sharded_sgd_allreduce_n_vector_cocoa_m_vector():
    """Paper §5.4: mini-batch SGD all-reduces the n-dim gradient while
    CoCoA all-reduces the m-dim Delta v — more traffic whenever n > m,
    and it must be visible in the HLO."""
    _run("""
import re, jax, jax.random as jr
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer, MinibatchSGD, SGDConfig
from repro.utils.compat import make_mesh
m, n = 96, 256
A, b, _ = make_glm_data(m=m, n=n, density=0.2, seed=1)
mesh = make_mesh((4,), ("workers",))
def hlo(tr):
    rf = tr.build_sharded_round(mesh)
    local, shared = tr.init_state()
    return rf.jitted.lower(rf.split_keys(jr.key(0)),
                           local, shared, 1).compile().as_text()
coc = hlo(CoCoATrainer(CoCoAConfig(K=4, H=32), A, b))
sgd = hlo(MinibatchSGD(SGDConfig(K=4, step_size=0.1), A, b))
assert re.search(rf"f32\\[{m}\\]\\S* all-reduce", coc), "m-vector all-reduce missing"
assert not re.search(rf"f32\\[{n}\\]\\S* all-reduce", coc), "CoCoA must not move an n-vector"
assert re.search(rf"f32\\[{n}\\]\\S* all-reduce", sgd), "n-vector all-reduce missing"
assert not re.search(rf"f32\\[{m}\\]\\S* all-reduce", sgd), "SGD must not move an m-vector"
print("OK")
""", ndev=4)


def test_compressed_quantizer_bit_identical_across_drivers():
    """Both drivers call the ONE shared quantization helper, so the
    dequantized updates — and their aggregate — are bit-identical
    between the virtual and sharded paths."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.distributed import (CommScheme, quantize_update,
                                    dequantize_update)
from repro.utils.compat import make_mesh, shard_map
K, m = 4, 96
dv = jax.random.normal(jax.random.key(7), (K, m), jnp.float32)
dv = dv * (10.0 ** jnp.arange(-2, K - 2, dtype=jnp.float32))[:, None]
mesh = make_mesh((K,), ("workers",))
# per-worker dequantized updates: vmapped helper vs per-shard helper
q, s = jax.vmap(quantize_update)(dv)
virt = dequantize_update(q, s[:, None])
f = shard_map(lambda d: dequantize_update(*quantize_update(d[0]))[None],
              mesh, in_specs=P("workers"), out_specs=P("workers"))
shrd = jax.jit(f)(dv)
assert np.array_equal(np.asarray(virt), np.asarray(shrd)), "per-worker drift"
# the aggregated update the round actually applies
scheme = CommScheme.parse("compressed")
agg_v = scheme.all_reduce_stacked(dv)
g = shard_map(lambda d: scheme.all_reduce(d[0], "workers"), mesh,
              in_specs=P("workers"), out_specs=P(None))
agg_s = jax.jit(g)(dv)
assert np.array_equal(np.asarray(agg_v), np.asarray(agg_s)), "aggregate drift"
print("OK")
""", ndev=4)


def test_moe_sharded_matches_global():
    _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import layers as L
cfg = get_config("deepseek-v3-671b").reduced()
from repro.utils.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
p = L.init_moe(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32) * 0.1
L.set_partitioning(dp=("data",), tp="model", mesh=mesh)
with mesh:
    y1, _ = jax.jit(lambda p, x: L.moe_apply(p, cfg, x))(p, x)
L.set_partitioning()
y2, _ = L.moe_apply(p, cfg, x)
d = float(jnp.max(jnp.abs(y1 - y2)))
assert d < 1e-5, d
print("OK")
""")


def test_local_updates_H1_sgd_equals_sync_dp():
    """With plain SGD, H=1 local updates == synchronous data parallelism
    (gradient averaging) — the paper's knob reduces to the baseline."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim import LocalUpdatesConfig, local_updates_round
from repro.utils.compat import make_mesh
mesh = make_mesh((4,), ("data",))
lr = 0.1
def loss(w, b):
    x, y = b
    return jnp.mean((x @ w - y) ** 2)
def sgd_step(w, o, b):
    g = jax.grad(loss)(w, b)
    return w - lr * g, o, {}
rng = np.random.default_rng(0)
X = jnp.asarray(rng.standard_normal((8, 4, 3)), jnp.float32)  # 8 shards*... (4 per shard? -> (4 shards,2,... )
X = jnp.asarray(rng.standard_normal((4, 1, 6, 3)), jnp.float32)  # (shards, H=1, batch, feat)
Y = jnp.asarray(rng.standard_normal((4, 1, 6)), jnp.float32)
w0 = jnp.zeros((3,))
# reference: one sync step on the full data
g_full = jax.grad(loss)(w0, (X.reshape(-1, 3), Y.reshape(-1)))
w_ref = w0 - lr * g_full
# local-updates H=1 via shard_map over data
def round_fn(w, Xs, Ys):
    def body(Xl, Yl, w):
        cfg = LocalUpdatesConfig(H=1)
        w2, _, _ = local_updates_round(sgd_step, w, {}, (Xl[0], Yl[0]), cfg, "data")
        return w2
    from repro.utils.compat import shard_map
    return shard_map(body, mesh,
        in_specs=(P("data"), P("data"), P(None)), out_specs=P(None))(Xs, Ys, w)
w_lu = jax.jit(round_fn)(w0, X, Y)
assert float(jnp.max(jnp.abs(w_lu - w_ref))) < 1e-6, (w_lu, w_ref)
print("OK")
""")


def test_local_updates_codec_delta_exchange():
    """The transformer local-SGD workload's compressed exchange: with a
    quantizing codec the delta exchange all-gathers encoded payloads
    and decodes+means locally. H=2 local SGD on a 4-shard toy problem:
    the int8 result must track the exact f32 pmean to the codec's grid
    error, int4 coarser but bounded, and an all-zero delta (lr=0) must
    come back EXACTLY zero — the codec layer's zero guarantee, end to
    end through shard_map."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import LocalUpdatesConfig, local_updates_round
from repro.utils.compat import make_mesh, shard_map
mesh = make_mesh((4,), ("data",))
def loss(w, b):
    x, y = b
    return jnp.mean((x @ w - y) ** 2)
def make_step(lr):
    def sgd_step(w, o, b):
        return w - lr * jax.grad(loss)(w, b), o, {}
    return sgd_step
rng = np.random.default_rng(0)
X = jnp.asarray(rng.standard_normal((4, 2, 6, 3)), jnp.float32)  # (shards, H=2, batch, feat)
Y = jnp.asarray(rng.standard_normal((4, 2, 6)), jnp.float32)
w0 = jnp.asarray(rng.standard_normal(3), jnp.float32)
def run(codec, lr):
    cfg = LocalUpdatesConfig(H=2, codec=codec)
    def body(Xl, Yl, w):
        w2, _, _ = local_updates_round(make_step(lr), w, {}, (Xl[0], Yl[0]),
                                       cfg, "data")
        return w2
    f = shard_map(body, mesh, in_specs=(P("data"), P("data"), P(None)),
                  out_specs=P(None))
    return jax.jit(f)(X, Y, w0)
w_f32 = run("f32", 0.05)
d_f32 = np.abs(np.asarray(w_f32) - np.asarray(w0)).max()
assert d_f32 > 0, "reference round did not move"
for codec, mult in (("int8", 1.0), ("int4", 17.0), ("int2", 85.0)):
    w_c = run(codec, 0.05)
    err = np.abs(np.asarray(w_c) - np.asarray(w_f32)).max()
    # the averaged delta's error is bounded by the mean of per-shard
    # grid errors; compare against the f32 delta magnitude with the
    # codec's grid-coarseness factor (int4 grid ~17x coarser)
    assert err <= 0.02 * mult * max(d_f32, 1e-9), (codec, err, d_f32)
# lr=0: every shard's delta is exactly zero -> the decoded mean must be
# exactly w0 under EVERY codec (the zero-input guarantee through the
# whole exchange)
for codec in ("f32", "int8", "int4", "int2", "topk(r=0.25)", "ef:int4"):
    w_z = run(codec, 0.0)
    assert np.array_equal(np.asarray(w_z), np.asarray(w0)), codec
print("OK")
""", ndev=4)


def test_local_updates_delta_bytes_match_hlo():
    """Satellite of the byte-model repair: lower ONE delta exchange per
    codec (sync_opt_state off to isolate it) and pin delta_wire_bytes
    against the HLO-derived bytes — the f32 pmean all-reduce, the
    quantized all-gathers, topk's live threshold gather (decode consumes
    it, so XLA cannot dead-code it away), and the ef: state threading
    all price exactly."""
    _run("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.optim.local_updates import (LocalUpdatesConfig, local_updates_round,
                                       delta_wire_bytes, init_delta_codec_state)
from repro.utils.compat import make_mesh, shard_map
from repro.analysis.graph import lift_hlo
from repro.analysis.traffic import derived_round_traffic

K = 4
mesh = make_mesh((K,), ("data",))

def step_fn(p, o, mb):
    g = jax.tree.map(lambda x: x * 0.01 + mb["x"].sum() * 0, p)
    return jax.tree.map(lambda a, b: a - 0.1 * b, p, g), o, {"loss": mb["x"].sum()}

params = {"w": jnp.ones((96,)) * 0.3, "b": jnp.ones((33,)) * -0.2}
batches = {"x": jnp.zeros((K, 2, 8))}

class Duck:
    backend = None
    class scheme: transport = "compressed"

for codec in ("f32", "int8", "int4", "int2", "topk(r=0.125)",
              "ef:int4", "ef:int2", "ef:topk(r=0.125)"):
    cfg = LocalUpdatesConfig(H=2, codec=codec, sync_opt_state=False)
    cstate = init_delta_codec_state(params, cfg)
    if cstate is None:
        def run(p, b):
            pH, oH, m = local_updates_round(step_fn, p, {}, b, cfg, "data")
            return pH, m["loss"].sum()[None]
        f = shard_map(run, mesh, in_specs=(P(), P("data")),
                      out_specs=(P(), P("data")))
        hlo = jax.jit(f).lower(params, batches).compile().as_text()
    else:
        cstateK = jax.tree.map(lambda s: jnp.stack([s] * K), cstate)
        def run(p, b, cs):
            cs = jax.tree.map(lambda x: x[0], cs)
            pH, oH, m, cs = local_updates_round(step_fn, p, {}, b, cfg,
                                                "data", codec_state=cs)
            return pH, m["loss"].sum()[None], jax.tree.map(
                lambda x: x[None], cs)
        f = shard_map(run, mesh, in_specs=(P(), P("data"), P("data")),
                      out_specs=(P(), P("data"), P("data")))
        hlo = jax.jit(f).lower(params, batches, cstateK).compile().as_text()
    derived = derived_round_traffic(lift_hlo(hlo), Duck, K)
    model = delta_wire_bytes(params, cfg, K)
    assert derived == model, (codec, derived, model)
print("OK")
""", ndev=4)


def test_ef_wrapper_residual_semantics():
    """EFWrapper unit contracts: (a) the zero-residual entry point is
    bitwise the base codec; (b) encode_with_state returns residual =
    (dv + state) - decode(parts); (c) iterating on a constant update
    keeps the residual bounded while the MEAN decoded update converges
    to the true value (the error is delayed, not destroyed) — where
    plain int4 holds a permanent bias on the same input."""
    import jax
    import jax.numpy as jnp

    from repro.comm import get_codec

    base = get_codec("int4")
    ef = get_codec("ef:int4")
    rng = np.random.default_rng(7)
    dv = jnp.asarray(rng.standard_normal(96) * 0.1, jnp.float32)
    for pb, pe in zip(base.encode(dv), ef.encode(dv)):
        assert np.array_equal(np.asarray(pb), np.asarray(pe))
    state = ef.init_state(96)
    assert state.shape == (96,) and not np.any(np.asarray(state))
    parts, new_state = jax.jit(ef.encode_with_state)(dv, state)
    expect = np.asarray(dv) - np.asarray(base.decode(parts, 96))
    assert np.allclose(np.asarray(new_state), expect, atol=1e-7)

    @jax.jit
    def step(state):
        parts, state = ef.encode_with_state(dv, state)
        return ef.decode(parts, 96), state

    decoded_sum = jnp.zeros(96)
    for t in range(200):
        dec, state = step(state)
        decoded_sum = decoded_sum + dec
        assert float(jnp.linalg.norm(state)) < 10.0, t  # bounded residual
    mean_err = float(jnp.max(jnp.abs(decoded_sum / 200 - dv)))
    plain_err = float(jnp.max(jnp.abs(base.decode(base.encode(dv), 96) - dv)))
    assert mean_err < 0.2 * plain_err, (mean_err, plain_err)


def test_stateful_codec_widens_local_slot():
    """wrap_local_state/unwrap_local_state: identity (the SAME object)
    for stateless codecs — the sync/f32 drivers are untouched by the
    EF machinery — and a (local, (K, L) zeros) pair for ef: codecs."""
    import jax.numpy as jnp

    from repro.core import distributed as dist

    local = jnp.ones((4, 7))
    for spec in ("persistent", "compressed:int4", "compressed:topk(r=0.5)"):
        assert dist.wrap_local_state(spec, local, 96, 4) is local
        assert dist.unwrap_local_state(spec, local) is local
    wrapped = dist.wrap_local_state("compressed:ef:int4", local, 96, 4)
    assert isinstance(wrapped, tuple) and wrapped[0] is local
    assert wrapped[1].shape == (4, 96) and not np.any(np.asarray(wrapped[1]))
    assert dist.unwrap_local_state("compressed:ef:int4", wrapped) is local


def test_ef_codec_lifts_int4_floor_virtual_driver():
    """The headline, at unit-test scale on the virtual driver: plain
    compressed:int4 floors well above the duality gap compressed:ef:int4
    reaches on the same problem/rounds — error feedback converts the
    biased grid's floor into convergence."""
    from repro.core import CoCoAConfig, CoCoATrainer
    from repro.data import make_glm_data

    A, b, _ = make_glm_data(m=48, n=96, density=0.3, zipf_a=1.1, seed=3)

    def gap(exchange):
        tr = CoCoATrainer(CoCoAConfig(K=4, H=24, lam=1.0, solver="scd_ref",
                                      exchange=exchange, seed=0), A, b)
        return tr.run(rounds=40, record_every=40).subopt[-1]

    g_int4 = gap("compressed:int4")
    g_ef = gap("compressed:ef:int4")
    assert g_ef < 1e-3, g_ef
    assert g_int4 > 20 * g_ef, (g_int4, g_ef)


def test_ef_sharded_matches_virtual_under_regimes():
    """Codec-state threading through the sharded driver: ef:int4 under
    plain sync, bounded staleness, and elastic membership must track the
    virtual driver's trajectory bit-tight (the widened local slot rides
    the same wrap/unwrap path in both drivers)."""
    _run("""
import numpy as np
from repro.core import CoCoAConfig, CoCoATrainer
from repro.data import make_glm_data
A, b, _ = make_glm_data(m=48, n=96, density=0.3, zipf_a=1.1, seed=3)
for spec in ("compressed:ef:int4", "compressed:ef:int4/stale:k=2",
             "compressed:ef:int4/drop:1@2-4"):
    hv = CoCoATrainer(CoCoAConfig(K=4, H=24, lam=1.0, solver="scd_ref",
                                  exchange=spec, seed=0), A, b) \
        .run(rounds=10, record_every=2)
    hs = CoCoATrainer(CoCoAConfig(K=4, H=24, lam=1.0, solver="scd_ref",
                                  exchange=spec, seed=0), A, b) \
        .run_sharded(rounds=10, record_every=2)
    dp = np.max(np.abs(np.asarray(hv.primal) - np.asarray(hs.primal)))
    assert dp < 1e-5, (spec, dp)
print("OK")
""", ndev=4)


def test_dryrun_production_mesh_smoke():
    """The real deliverable-(e) path: tinyllama decode on the 16x16 and
    2x16x16 meshes must lower + compile in a 512-device subprocess.
    (`slow` tier — marked from the registry in conftest.py, not here.)"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
         "--both-meshes", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=560, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "all dry-runs OK" in out.stdout


def test_cocoa_compressed_int8_collective():
    """The compressed scheme's collective moves int8, not f32."""
    _run("""
import numpy as np, jax, jax.random as jr, re
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
tr = CoCoATrainer(CoCoAConfig(K=8, H=32, exchange="compressed"), A, b)
from repro.utils.compat import make_mesh
mesh = make_mesh((8,), ("workers",))
rf = tr.build_sharded_round(mesh)
alpha, w = tr.init_state()
txt = rf.jitted.lower(rf.split_keys(jr.key(0)), alpha, w, 1).compile().as_text()
assert re.search(r"s8\\[[0-9,]+\\][^ ]* all-gather", txt), "int8 all-gather missing"
h = tr.run_sharded(rounds=25, record_every=25)
assert h.subopt[-1] < 5e-2, h.subopt
print("OK")
""")
