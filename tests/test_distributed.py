"""Multi-device tests (subprocess with faked host devices): shard_map
CoCoA driver, expert-parallel MoE, local-update rounds, and a dry-run
smoke on the production mesh.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py: str, ndev: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_cocoa_sharded_matches_virtual():
    _run("""
import numpy as np, jax
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
cfg = CoCoAConfig(K=8, H=64, seed=3)
t1 = CoCoATrainer(cfg, A, b); h1 = t1.run(rounds=20, record_every=20)
t2 = CoCoATrainer(cfg, A, b); h2 = t2.run_sharded(rounds=20, record_every=20)
# identical algorithm, identical rng -> identical trajectories
assert abs(h1.primal[-1] - h2.primal[-1]) / abs(h1.primal[-1]) < 1e-4, (h1.primal, h2.primal)
print("OK")
""")


def test_cocoa_spark_faithful_extra_collectives():
    _run("""
import numpy as np, jax, jax.random as jr
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
from repro.utils.hlo import parse_collectives
from repro.utils.compat import make_mesh
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
texts = {}
for scheme in ("persistent", "spark_faithful"):
    tr = CoCoATrainer(CoCoAConfig(K=8, H=32, comm_scheme=scheme), A, b)
    mesh = make_mesh((8,), ("workers",))
    rf = tr.build_sharded_round(mesh)
    alpha, w = tr.init_state()
    low = jax.jit(lambda a, w, k: rf(a, w, k)).lower(alpha, w, jr.key_data(jr.key(0)))
    texts[scheme] = parse_collectives(low.compile().as_text())
p, s = texts["persistent"], texts["spark_faithful"]
assert "all-gather" in s.by_kind and "all-gather" not in p.by_kind
assert s.total_operand_bytes > p.total_operand_bytes
print("OK")
""")


def test_moe_sharded_matches_global():
    _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import layers as L
cfg = get_config("deepseek-v3-671b").reduced()
from repro.utils.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
p = L.init_moe(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32) * 0.1
L.set_partitioning(dp=("data",), tp="model", mesh=mesh)
with mesh:
    y1, _ = jax.jit(lambda p, x: L.moe_apply(p, cfg, x))(p, x)
L.set_partitioning()
y2, _ = L.moe_apply(p, cfg, x)
d = float(jnp.max(jnp.abs(y1 - y2)))
assert d < 1e-5, d
print("OK")
""")


def test_local_updates_H1_sgd_equals_sync_dp():
    """With plain SGD, H=1 local updates == synchronous data parallelism
    (gradient averaging) — the paper's knob reduces to the baseline."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim import LocalUpdatesConfig, local_updates_round
from repro.utils.compat import make_mesh
mesh = make_mesh((4,), ("data",))
lr = 0.1
def loss(w, b):
    x, y = b
    return jnp.mean((x @ w - y) ** 2)
def sgd_step(w, o, b):
    g = jax.grad(loss)(w, b)
    return w - lr * g, o, {}
rng = np.random.default_rng(0)
X = jnp.asarray(rng.standard_normal((8, 4, 3)), jnp.float32)  # 8 shards*... (4 per shard? -> (4 shards,2,... )
X = jnp.asarray(rng.standard_normal((4, 1, 6, 3)), jnp.float32)  # (shards, H=1, batch, feat)
Y = jnp.asarray(rng.standard_normal((4, 1, 6)), jnp.float32)
w0 = jnp.zeros((3,))
# reference: one sync step on the full data
g_full = jax.grad(loss)(w0, (X.reshape(-1, 3), Y.reshape(-1)))
w_ref = w0 - lr * g_full
# local-updates H=1 via shard_map over data
def round_fn(w, Xs, Ys):
    def body(Xl, Yl, w):
        cfg = LocalUpdatesConfig(H=1)
        w2, _, _ = local_updates_round(sgd_step, w, {}, (Xl[0], Yl[0]), cfg, "data")
        return w2
    from repro.utils.compat import shard_map
    return shard_map(body, mesh,
        in_specs=(P("data"), P("data"), P(None)), out_specs=P(None))(Xs, Ys, w)
w_lu = jax.jit(round_fn)(w0, X, Y)
assert float(jnp.max(jnp.abs(w_lu - w_ref))) < 1e-6, (w_lu, w_ref)
print("OK")
""")


@pytest.mark.slow
def test_dryrun_production_mesh_smoke():
    """The real deliverable-(e) path: tinyllama decode on the 16x16 and
    2x16x16 meshes must lower + compile in a 512-device subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
         "--both-meshes", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=560, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "all dry-runs OK" in out.stdout


def test_cocoa_compressed_int8_collective():
    """The compressed scheme's collective moves int8, not f32."""
    _run("""
import numpy as np, jax, jax.random as jr, re
from repro.data import make_glm_data
from repro.core import CoCoAConfig, CoCoATrainer
A, b, _ = make_glm_data(m=128, n=256, density=0.3, seed=1)
tr = CoCoATrainer(CoCoAConfig(K=8, H=32, comm_scheme="compressed"), A, b)
from repro.utils.compat import make_mesh
mesh = make_mesh((8,), ("workers",))
rf = tr.build_sharded_round(mesh)
alpha, w = tr.init_state()
txt = jax.jit(lambda a,w,k: rf(a,w,k)).lower(alpha, w, jr.key_data(jr.key(0))).compile().as_text()
assert re.search(r"s8\\[[0-9,]+\\][^ ]* all-gather", txt), "int8 all-gather missing"
h = tr.run_sharded(rounds=25, record_every=25)
assert h.subopt[-1] < 5e-2, h.subopt
print("OK")
""")
