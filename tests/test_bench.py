"""The bench harness itself: registry, schema round-trip, compare gate,
and a smoke-tier end-to-end run on a tiny problem."""
import json

import pytest

from repro.bench import compare as cmp_mod
from repro.bench import registry, schema
from repro.bench.run import run_benchmarks
from repro.bench.timing import TimingPolicy


# ---------------------------------------------------------------- registry
def test_registry_registration_and_lookup():
    @registry.benchmark("_test_dummy", figures="none")
    def dummy(ctx):
        """A dummy benchmark."""
        return {"timings_s": {"x": 1.0}}

    try:
        spec = registry.get("_test_dummy")
        assert spec.fn is dummy
        assert spec.description == "A dummy benchmark."
        assert "_test_dummy" in registry.names()
        # duplicate name with a different function is rejected
        with pytest.raises(ValueError, match="already registered"):
            @registry.benchmark("_test_dummy")
            def other(ctx):
                return {}
    finally:
        registry._REGISTRY.pop("_test_dummy", None)


def test_registry_loads_all_ported_benchmarks():
    names = registry.load_default_benchmarks()
    assert {"overheads", "h_sweep", "convergence", "kernels", "roofline",
            "scaling", "drivers"} <= set(names)


def test_unknown_benchmark_and_tier():
    with pytest.raises(KeyError, match="unknown benchmark"):
        registry.get("_no_such_bench")
    with pytest.raises(ValueError, match="unknown tier"):
        registry.BenchContext(tier="warp")


# ------------------------------------------------------------------ schema
def _result(**over):
    kw = dict(benchmark="demo", tier="smoke",
              env=schema.EnvFingerprint.capture(),
              params={"m": 8}, timings_s={"t": 0.5}, counters={"r": 3},
              rows=[{"a": 1}], notes=["n"])
    kw.update(over)
    return schema.BenchResult(**kw)


def test_schema_roundtrip(tmp_path):
    res = _result()
    path = res.write(str(tmp_path))
    assert path.endswith("BENCH_demo.json")
    back = schema.load(path)
    assert back.benchmark == "demo"
    assert back.timings_s == {"t": 0.5}
    assert back.env.jax == res.env.jax
    assert back.schema_version == schema.SCHEMA_VERSION


def test_schema_validation_rejects_junk(tmp_path):
    res = _result()
    d = res.to_dict()
    d["schema_version"] = 999
    d["timings_s"] = {"t": "fast"}
    p = tmp_path / "BENCH_bad.json"
    p.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="schema_version"):
        schema.load(str(p))
    assert any("timings_s" in s for s in schema.validate(d))


# ----------------------------------------------------------------- compare
def test_compare_same_passes_slowdown_fails(tmp_path):
    old = _result(timings_s={"step": 0.1, "round": 0.02})
    same = _result(timings_s={"step": 0.1, "round": 0.02})
    deltas = cmp_mod.compare_results(old, same, max_regression=1.25)
    assert not any(d.regression for d in deltas)
    slow = _result(timings_s={"step": 0.15, "round": 0.02})  # +50%
    deltas = cmp_mod.compare_results(old, slow, max_regression=1.25)
    assert [d.metric for d in deltas if d.regression] == ["step"]
    fast = _result(timings_s={"step": 0.01, "round": 0.01})  # improvement
    assert not any(d.regression
                   for d in cmp_mod.compare_results(old, fast, 1.25))


def test_compare_cli_exit_codes(tmp_path):
    old_dir, new_dir = tmp_path / "old", tmp_path / "new"
    _result().write(str(old_dir))
    _result().write(str(new_dir))
    assert cmp_mod.main([str(old_dir), str(new_dir)]) == 0
    _result(timings_s={"t": 5.0}).write(str(new_dir))  # 10x slower
    assert cmp_mod.main([str(old_dir), str(new_dir),
                         "--max-regression", "1.25"]) == 1


def test_compare_min_time_floor():
    old = _result(timings_s={"tiny": 1e-6})
    new = _result(timings_s={"tiny": 3e-6})  # 3x, but below the floor
    deltas = cmp_mod.compare_results(old, new, max_regression=1.25,
                                     min_time_s=1e-4)
    assert not any(d.regression for d in deltas)


# ----------------------------------------------------- exact-counter gate
_BYTES = "comm_bytes_per_round_cocoa_persistent"


def test_exact_counter_passes_on_equal_counters(tmp_path):
    old_dir, new_dir = tmp_path / "old", tmp_path / "new"
    counters = {_BYTES: 3072, f"{_BYTES}_stale": 3072, "rounds_to_eps_x": 15}
    _result(counters=dict(counters)).write(str(old_dir))
    _result(counters=dict(counters)).write(str(new_dir))
    assert cmp_mod.main([str(old_dir), str(new_dir),
                         "--exact-counter", "comm_bytes_per_round_"]) == 0


def test_exact_counter_fails_on_one_byte_drift(tmp_path):
    old_dir, new_dir = tmp_path / "old", tmp_path / "new"
    _result(counters={_BYTES: 3072}).write(str(old_dir))
    _result(counters={_BYTES: 3073}).write(str(new_dir))  # one byte off
    assert cmp_mod.main([str(old_dir), str(new_dir),
                         "--exact-counter", "comm_bytes_per_round_"]) == 1
    # ...while without the flag the drifted counter is not gated at all
    assert cmp_mod.main([str(old_dir), str(new_dir)]) == 0
    # and the gate really is exact equality, not a tolerance: the delta
    # itself flags the 1-byte drift
    deltas = cmp_mod.compare_counters(
        _result(counters={_BYTES: 3072}), _result(counters={_BYTES: 3073}),
        ["comm_bytes_per_round_"])
    assert [d.regression for d in deltas] == [True]


def test_exact_counter_ignores_K_suffixed_on_full_mesh_baseline(tmp_path):
    """A device-starved candidate emits `_K<n>`-suffixed byte counters
    (its sharded worker count differs), which must NOT pair with — and
    spuriously fail against — a full-mesh baseline: counters present on
    only one side are skipped, both ways."""
    old_dir, new_dir = tmp_path / "old", tmp_path / "new"
    # full-mesh baseline: unsuffixed counters at K=4
    _result(counters={_BYTES: 3072}).write(str(old_dir))
    # device-starved candidate: same cell at K=2, suffixed (and with
    # genuinely different bytes — exactly why it must not be compared)
    _result(counters={f"{_BYTES}_K2": 1536}).write(str(new_dir))
    assert cmp_mod.main([str(old_dir), str(new_dir),
                         "--exact-counter", "comm_bytes_per_round_"]) == 0
    deltas = cmp_mod.compare_counters(
        _result(counters={_BYTES: 3072}),
        _result(counters={f"{_BYTES}_K2": 1536}),
        ["comm_bytes_per_round_"])
    assert deltas == []  # nothing paired, nothing gated


# ------------------------------------------------------------------ timing
def test_timing_policy_reduce():
    assert TimingPolicy(reduce="min").combine([3.0, 1.0, 2.0]) == 1.0
    assert TimingPolicy(reduce="median").combine([3.0, 1.0, 2.0]) == 2.0
    with pytest.raises(ValueError):
        TimingPolicy(reduce="max").combine([1.0])


# ------------------------------------------------------- end-to-end smoke
def test_smoke_tier_end_to_end(tmp_path):
    """One sweep-backed benchmark + the driver/comm-scheme coverage
    benchmark, smoke tier, in-process (1 device -> K=1 sharded mesh).
    Checks emitted files are schema-valid and carry gateable timings."""
    results = run_benchmarks(tier="smoke", only=["kernels", "drivers"],
                             out_dir=str(tmp_path), verbose=False)
    by = {r.benchmark: r for r in results}
    assert by["kernels"].status == "ok"
    assert by["drivers"].status == "ok"
    for name in ("kernels", "drivers"):
        loaded = schema.load(str(tmp_path / schema.result_filename(name)))
        assert loaded.tier == "smoke"
        assert loaded.timings_s, name
        assert loaded.env.device_count >= 1
    # drivers must cover the full matrix: 3 algorithms x both execution
    # drivers x every transport-x-codec scheme x both exchange modes
    # (72 rows — the 36 modelled-bytes cells each run on both drivers)
    # ... plus the regime cells (full ExchangeConfig specs: straggler,
    # bounded staleness, elastic membership) and the collective-backend
    # cells (ring fabric); a regime cell's sharded leg is skipped on a
    # device-starved mesh (membership events name absolute worker
    # indices the smaller mesh cannot host)
    from benchmarks.bench_drivers import (BACKEND_CELLS, CODEC_CELLS,
                                          REGIME_CELLS)
    from repro.core import ExchangeConfig

    got = {(r["algorithm"], r["driver"], r["scheme"], r["mode"])
           for r in by["drivers"].rows}
    k_sh = by["drivers"].params["K_sharded"]
    k_virt = by["drivers"].params["K_virtual"]
    expected = {(a, d, s, m)
                for a in ("cocoa", "minibatch_scd", "minibatch_sgd")
                for d in ("virtual", "sharded")
                for s in ("persistent", "spark_faithful",
                          "compressed:f32", "compressed:int8",
                          "compressed:int4", "reduce_scatter")
                for m in ("sync", "stale")}
    for algo, spec in REGIME_CELLS + BACKEND_CELLS + CODEC_CELLS:
        ex = ExchangeConfig.parse(spec)
        drivers = (("virtual", "sharded")
                   if ex.membership.empty or k_sh == k_virt
                   else ("virtual",))
        expected |= {(algo, d, spec, ex.mode.spec) for d in drivers}
    assert got == expected
    # every compressed row is labelled with its codec
    assert {r["codec"] for r in by["drivers"].rows
            if r["scheme"].startswith("compressed")} == {
        "f32", "int8", "int4", "int2", "topk(r=0.125)",
        "ef:int4", "ef:int2", "ef:topk(r=0.125)"}
    # every cell reports modelled bytes sized to the scheme's dtypes —
    # except reduce_scatter and the ring backend on a single-device
    # mesh, whose ring volumes are genuinely zero at K=1
    k_sh = by["drivers"].params["K_sharded"]
    for r in by["drivers"].rows:
        if k_sh == 1 and (r["scheme"] == "reduce_scatter"
                          or "/ring" in r["scheme"]):
            assert r["comm_bytes_per_round"] == 0
        else:
            assert r["comm_bytes_per_round"] > 0
