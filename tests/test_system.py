"""End-to-end behaviour tests for the paper's system.

1. The full CoCoA pipeline (data -> partition -> kernel-solver training
   -> suboptimality) reaches the paper's target eps=1e-3.
2. The transformer substrate trains a reduced model to decreasing loss.
3. The H trade-off is visible end-to-end: under an MPI-like cost profile
   a smaller H wins; under a Spark-like profile a larger H wins.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CoCoAConfig, CoCoATrainer, PROFILES
from repro.core.tradeoff import HSweep, HSweepPoint, optimal_H
from repro.data import make_glm_data
from repro.data.tokens import TokenStream


def test_end_to_end_cocoa_with_pallas_kernel_solver():
    A, b, _ = make_glm_data(m=192, n=384, density=0.25, seed=9)
    cfg = CoCoAConfig(K=4, H=128, solver="scd_kernel")
    tr = CoCoATrainer(cfg, A, b)
    hist = tr.run(rounds=120, record_every=10, target_eps=1e-3)
    assert hist.subopt[-1] <= 1e-3
    # kernel solver and reference solver converge to the same model
    tr2 = CoCoATrainer(CoCoAConfig(K=4, H=128, solver="scd_ref"), A, b)
    tr2.run(rounds=120, record_every=10, target_eps=1e-3)
    assert np.linalg.norm(tr.alpha_final - tr2.alpha_final) / \
        max(np.linalg.norm(tr2.alpha_final), 1e-9) < 0.05


def test_end_to_end_lm_training_loss_decreases():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamWConfig, adamw_init
    from repro.train import make_train_step

    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    ts = TokenStream(cfg.vocab_size, 128, 8, seed=0)
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in ts.next_batch().items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_end_to_end_h_tradeoff_flips_with_framework():
    """Measured rounds-to-eps over an H grid + the calibrated overhead
    profiles => the optimal H must shift upward from MPI to pySpark."""
    A, b, _ = make_glm_data(m=160, n=320, density=0.3, seed=5)
    sweep = HSweep(eps=1e-3, n_local=80, t_ref_s=0.08)  # t_ref: 80-step solve
    for H in (8, 32, 128, 512):
        tr = CoCoATrainer(CoCoAConfig(K=4, H=H, seed=2), A, b)
        hist = tr.run(rounds=600, record_every=1, target_eps=1e-3)
        sweep.points.append(
            HSweepPoint(H, hist.rounds_to(1e-3), t_solver_s=H * 1e-3))
    h_mpi, _ = optimal_H(PROFILES["E_mpi"], sweep)
    h_py, _ = optimal_H(PROFILES["D_pyspark_c"], sweep)
    assert h_py >= h_mpi
    assert h_py >= 128  # heavy overhead -> amortize with many local steps
