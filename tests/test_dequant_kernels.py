"""Bit-identity of the fused gather-side Pallas kernels against their
codec oracles (interpret mode on CPU — the same lowering contract the
comm layer relies on when it dispatches to the kernels on TPU), plus a
driver-level regression pinning the ``compressed:int4`` trajectory to
the sequential decode+reduce contract.

All comparisons are jitted-vs-jitted: XLA may lower an op-by-op eager
dispatch differently, and the contract pinned here is the one the
drivers execute."""
from __future__ import annotations

import functools

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.comm.codec import get_codec  # noqa: E402
from repro.kernels import (decode_reduce_int2, decode_reduce_int4,  # noqa: E402
                           decode_reduce_int8, decode_stacked_ref,
                           topk_select, topk_select_ref)

DECODE = {"int8": decode_reduce_int8,
          "int4": decode_reduce_int4,
          "int2": decode_reduce_int2}


@functools.cache
def _oracle(codec_name: str, length: int, mean: bool):
    """The jitted sequential-accumulation oracle from repro.kernels.ref
    (= the comm layer's off-TPU path)."""
    return jax.jit(lambda p, s: decode_stacked_ref(
        codec_name, (p, s), length, mean=mean))


def _gathered(codec_name: str, K: int, L: int, seed: int):
    """A (K, wire) payload + (K,) scales stack as the all-gather ships
    it: each worker row encoded independently."""
    codec = get_codec(codec_name)
    rng = np.random.default_rng(seed)
    parts = [codec.encode(jnp.asarray(
        rng.standard_normal(L) * 10.0 ** rng.integers(-3, 4), jnp.float32))
        for _ in range(K)]
    return (jnp.stack([p for p, _ in parts]),
            jnp.stack([s for _, s in parts]))


@pytest.mark.parametrize("codec_name", sorted(DECODE))
@pytest.mark.parametrize("K", [1, 3, 4, 8])
@pytest.mark.parametrize("L", [1, 5, 96, 127, 128, 129, 1000])
def test_decode_reduce_bit_identical_to_oracle(codec_name, K, L):
    """Fused decode+reduce == the sequential jnp oracle, bitwise, for
    both the mean and the sum reduction, across packing-boundary and
    odd lengths."""
    payload, scales = _gathered(codec_name, K, L, seed=K * 1000 + L)
    for mean in (True, False):
        want = _oracle(codec_name, L, mean)(payload, scales)
        got = DECODE[codec_name](payload, scales, L, mean=mean)
        assert want.shape == got.shape == (L,)
        assert np.array_equal(np.asarray(want), np.asarray(got)), (
            f"{codec_name} K={K} L={L} mean={mean}: fused kernel is "
            f"not bit-identical to the oracle")


@pytest.mark.parametrize("codec_name", sorted(DECODE))
def test_decode_reduce_zero_and_single_element(codec_name):
    """All-zero payloads reduce to exact zeros (every codec's guarded
    scale decodes code 0 to 0.0) and the L=1 single-element cell works
    at every K — the degenerate shapes the lane padding must not
    disturb."""
    codec = get_codec(codec_name)
    for K in (1, 2, 8):
        parts = [codec.encode(jnp.zeros(17, jnp.float32))
                 for _ in range(K)]
        payload = jnp.stack([p for p, _ in parts])
        scales = jnp.stack([s for _, s in parts])
        out = DECODE[codec_name](payload, scales, 17)
        assert (np.asarray(out) == 0).all(), (
            f"{codec_name} K={K}: zero payload decoded to nonzero mean")
        payload, scales = _gathered(codec_name, K, 1, seed=K)
        want = _oracle(codec_name, 1, True)(payload, scales)
        got = DECODE[codec_name](payload, scales, 1)
        assert np.array_equal(np.asarray(want), np.asarray(got))


def test_codec_dispatch_uses_the_oracle_contract():
    """``decode_stacked_sum`` / ``decode_stacked_mean`` on the
    quantizing codecs match the kernels' oracle bitwise — the dispatch
    seam the drivers and fabrics call through."""
    for codec_name in sorted(DECODE):
        codec = get_codec(codec_name)
        payload, scales = _gathered(codec_name, 4, 333, seed=7)
        for mean in (True, False):
            via_codec = jax.jit(
                codec.decode_stacked_mean if mean
                else codec.decode_stacked_sum,
                static_argnames="length")((payload, scales), 333)
            want = _oracle(codec_name, 333, mean)(payload, scales)
            assert np.array_equal(np.asarray(want), np.asarray(via_codec))


@pytest.mark.parametrize("L", [1, 2, 7, 96, 128, 129, 1000])
def test_topk_select_bit_identical_to_oracle(L):
    """The fused top-k select returns the same values, indices and
    threshold as ``lax.top_k`` over the magnitudes, bitwise."""
    codec = get_codec("topk(r=0.125)")
    k = codec._k(L)
    rng = np.random.default_rng(L)
    dv = jnp.asarray(rng.standard_normal(L), jnp.float32)
    v_ref, i_ref, t_ref = jax.jit(codec.encode_ref)(dv)
    v_ker, i_ker, t_ker = topk_select(dv, k)
    assert np.array_equal(np.asarray(v_ref), np.asarray(v_ker))
    assert np.array_equal(np.asarray(i_ref), np.asarray(i_ker))
    assert float(t_ref) == float(t_ker)


def test_topk_select_breaks_ties_like_the_oracle():
    """Duplicate magnitudes (including +x vs -x) select the lowest
    index first — ``lax.top_k``'s stable order, which the kernel's
    first-occurrence argmax must reproduce."""
    dv = jnp.asarray([2.0, -2.0, 1.0, 2.0, -1.0, 1.0, 0.0, -2.0],
                     jnp.float32)
    for k in (1, 2, 3, 5, 8):
        mags, idx = jax.lax.top_k(jnp.abs(dv), k)
        want_v, want_i, want_t = jnp.take(dv, idx), idx, mags[k - 1]
        got_v, got_i, got_t = topk_select(dv, k)
        assert np.array_equal(np.asarray(want_v), np.asarray(got_v)), k
        assert np.array_equal(np.asarray(want_i), np.asarray(got_i)), k
        assert float(want_t) == float(got_t), k


def test_compressed_int4_trajectory_pinned_to_oracle_contract():
    """Driver-level regression: a ``compressed:int4`` CoCoA run's
    iterates are bit-identical to a run whose gather-side reduce is
    forced through the explicit sequential oracle — pinning that the
    driver's aggregate IS the decode+reduce contract (on TPU this
    compares the fused kernel against the oracle end-to-end; on CPU it
    pins the dispatch seam)."""
    from repro.core import CoCoAConfig, CoCoATrainer
    from repro.data import make_glm_data

    A, b, _ = make_glm_data(m=48, n=96, density=0.3, seed=3)

    def run_rounds(force_oracle: bool):
        cfg = CoCoAConfig(K=4, H=24, lam=1.0, eta=1.0, solver="scd_ref",
                          exchange="compressed:int4", seed=0)
        tr = CoCoATrainer(cfg, A, b)
        codec = tr.scheme.codec
        orig = type(codec).decode_stacked_sum
        if force_oracle:
            patched = (lambda self, parts, length:
                       self.decode_reduce_ref(parts, length, mean=False))
            type(codec).decode_stacked_sum = patched
        try:
            hist = tr.run(6, record_every=1)
        finally:
            type(codec).decode_stacked_sum = orig
        return hist

    h_dispatch = run_rounds(force_oracle=False)
    h_oracle = run_rounds(force_oracle=True)
    assert np.array_equal(np.asarray(h_dispatch.primal),
                          np.asarray(h_oracle.primal)), (
        "compressed:int4 trajectory drifted between the codec dispatch "
        "and the explicit sequential oracle")
    assert np.array_equal(np.asarray(h_dispatch.subopt),
                          np.asarray(h_oracle.subopt))
