"""The unified ExchangeConfig surface: spec-grammar round-trips and
typed errors, the deprecated-knob folding (configs and module-level
lookups), straggler-profile determinism and barrier-factor formulas,
elastic-membership masks, and the bounded-staleness queue semantics
pinned against a plain-Python serial replay (flush under k>1, no
aggregate silently lost across a mid-flight worker drop).

The multi-device (shard_map) legs of these contracts live in
tests/test_distributed.py; everything here is in-process.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (COMM_SCHEMES, CoCoAConfig, CoCoATrainer,
                        ExchangeConfig, ExchangeMode, MembershipSchedule,
                        SGDConfig, StragglerProfile, get_mode, get_scheme,
                        resolve_exchange)
from repro.core.distributed import (CommScheme, build_virtual_round,
                                    finish_run, init_exchange_state)
from repro.data import make_glm_data


# ----------------------------------------------------------------- grammar
ROUNDTRIP_SPECS = (
    "persistent",
    "compressed:int4",
    "persistent/stale",
    "compressed:int4/stale:k=2",
    "spark_faithful/straggler:det(slow=4)",
    "persistent/straggler:mix(p=0.1,slow=8)",
    "reduce_scatter/straggler:lognormal(sigma=0.5)",
    "persistent/drop:1@5",
    "compressed:int8/stale:k=3/straggler:mix(p=0.1,slow=8)/drop:1@5-9",
    "persistent/drop:1@5-9/drop:3@7",
    "persistent/ring",
    "compressed:int4/ring/stale:k=2",
    "spark_faithful/ring/straggler:det(slow=4)/drop:1@5",
)


@pytest.mark.parametrize("spec", ROUNDTRIP_SPECS)
def test_exchange_spec_roundtrips(spec):
    ex = ExchangeConfig.parse(spec)
    assert ex.spec == spec
    assert ExchangeConfig.parse(ex.spec) == ex
    assert str(ex) == spec


def test_exchange_spec_segments_are_order_independent():
    a = ExchangeConfig.parse("compressed:int4/stale:k=2/drop:1@5")
    b = ExchangeConfig.parse("drop:1@5/stale:k=2/compressed:int4")
    assert a == b
    # ... and the canonical spelling always leads with the scheme
    assert b.spec == "compressed:int4/stale:k=2/drop:1@5"
    # the collective-backend segment is order-independent like the rest,
    # and canonically sits right after the scheme
    c = ExchangeConfig.parse("stale:k=2/ring/compressed:int4")
    assert c == ExchangeConfig.parse("compressed:int4/ring/stale:k=2")
    assert c.spec == "compressed:int4/ring/stale:k=2"


def test_exchange_spec_defaults_elided():
    assert ExchangeConfig.parse("persistent/sync").spec == "persistent"
    assert ExchangeConfig().spec == "persistent"
    ex = ExchangeConfig.parse("stale:k=2")
    assert ex.scheme.name == "persistent" and ex.mode.k == 2
    # the default xla backend is elided from the canonical spelling
    assert ExchangeConfig.parse("persistent/xla").spec == "persistent"
    assert ExchangeConfig(backend="xla").spec == "persistent"
    assert ExchangeConfig.parse("ring").spec == "persistent/ring"
    assert ExchangeConfig(backend="ring").backend == "ring"


def test_exchange_parse_passes_through_typed_values():
    ex = ExchangeConfig.parse("compressed:int4/stale:k=2")
    assert ExchangeConfig.parse(ex) is ex
    assert (ExchangeConfig.parse(CommScheme.parse("compressed:int4")).scheme
            == CommScheme.parse("compressed:int4"))
    assert ExchangeConfig.parse(ExchangeMode.parse("stale:k=2")).mode.k == 2
    # constructor convenience: components may be given as strings
    ex2 = ExchangeConfig(scheme="compressed:int4", mode="stale:k=2",
                         straggler="mix(p=0.1,slow=8)",
                         membership="drop:1@5")
    assert ex2.spec == ("compressed:int4/stale:k=2/"
                        "straggler:mix(p=0.1,slow=8)/drop:1@5")


def test_exchange_spec_typed_errors():
    with pytest.raises(ValueError, match="unknown exchange spec segment"):
        ExchangeConfig.parse("persistant")
    with pytest.raises(ValueError, match="the grammar is"):
        ExchangeConfig.parse("persistent/async")
    # a codec typo under a known transport head is a codec error
    with pytest.raises(ValueError, match="unknown update codec"):
        ExchangeConfig.parse("compressed:int3")
    with pytest.raises(ValueError, match="duplicate comm-scheme"):
        ExchangeConfig.parse("persistent/compressed")
    with pytest.raises(ValueError, match="duplicate exchange-mode"):
        ExchangeConfig.parse("sync/stale")
    with pytest.raises(ValueError, match="duplicate straggler"):
        ExchangeConfig.parse("straggler:det/straggler:mix")
    with pytest.raises(ValueError, match="unknown exchange mode"):
        ExchangeMode.parse("stale:k=x")
    with pytest.raises(ValueError, match="k must be >= 1"):
        ExchangeMode.parse("stale:k=0")
    with pytest.raises(ValueError, match="'sync' takes no staleness"):
        ExchangeMode("sync", k=2)
    with pytest.raises(ValueError, match="unknown straggler profile"):
        StragglerProfile.parse("pareto")
    with pytest.raises(ValueError, match="takes .* parameters"):
        StragglerProfile.parse("det(p=0.5)")
    with pytest.raises(ValueError, match="is not a number"):
        StragglerProfile.parse("mix(p=lots)")
    with pytest.raises(ValueError, match="malformed membership segment"):
        MembershipSchedule.parse("drop:1@")
    with pytest.raises(ValueError, match="last >= first"):
        MembershipSchedule.parse("drop:1@9-5")
    # collective-backend segment errors spell out the grammar
    with pytest.raises(ValueError, match="the grammar is"):
        ExchangeConfig.parse("persistent/nccl")
    with pytest.raises(ValueError, match="takes no parameters"):
        ExchangeConfig.parse("persistent/ring:fast")
    with pytest.raises(ValueError, match="duplicate collective-backend"):
        ExchangeConfig.parse("persistent/ring/xla")
    with pytest.raises(ValueError, match="duplicate collective-backend"):
        ExchangeConfig.parse("ring/persistent/ring")
    with pytest.raises(ValueError, match="unknown collective backend"):
        ExchangeConfig(backend="nccl")


# ------------------------------------------------- deprecated spellings
def test_module_level_lookups_warn_but_work():
    with pytest.warns(DeprecationWarning, match="get_scheme"):
        s = get_scheme("compressed:int4")
    assert s == CommScheme.parse("compressed:int4")
    with pytest.warns(DeprecationWarning, match="get_mode"):
        m = get_mode("stale")
    assert m == ExchangeMode.parse("stale")


def test_resolve_exchange_folding_rules():
    # legacy-only non-default values fold under ONE warning
    with pytest.warns(DeprecationWarning, match="comm_scheme"):
        ex = resolve_exchange(comm_scheme="compressed", exchange_mode="stale")
    assert ex.spec == "compressed/stale"
    # exchange authoritative + agreeing legacy ride-along: silent
    # (filterwarnings=error would fail this test if it warned)
    ex2 = resolve_exchange("compressed/stale", comm_scheme="compressed",
                           exchange_mode="stale")
    assert ex2 == ex
    # ... but a disagreeing legacy knob is a hard error, not a guess
    with pytest.raises(ValueError, match="drop the deprecated"):
        resolve_exchange("compressed/stale", comm_scheme="persistent")
    with pytest.raises(ValueError, match="drop the deprecated"):
        resolve_exchange("persistent/stale:k=2", exchange_mode="stale")
    # default legacy values never warn
    assert resolve_exchange(comm_scheme="persistent",
                            exchange_mode="sync").spec == "persistent"


def test_config_folds_and_replace_stays_silent():
    A, b, _ = make_glm_data(m=32, n=64, density=0.4, seed=0)
    cfg = CoCoAConfig(K=4, H=8, exchange="compressed:int4/stale:k=2")
    assert cfg.exchange.spec == "compressed:int4/stale:k=2"
    # the canonical legacy fields are kept in sync for introspection
    assert cfg.comm_scheme == "compressed:int4"
    assert cfg.exchange_mode == "stale:k=2"
    # dataclasses.replace re-passes those canonical values: it must
    # neither warn (error filter) nor change the exchange
    cfg2 = dataclasses.replace(cfg, H=16)
    assert cfg2.exchange == cfg.exchange and cfg2.H == 16
    sgd = SGDConfig(K=4, exchange="persistent/drop:2@3")
    assert dataclasses.replace(sgd, step_size=0.2).exchange == sgd.exchange
    # the membership schedule is validated against K at trainer build
    with pytest.raises(ValueError, match="only K=4 workers"):
        CoCoATrainer(CoCoAConfig(K=4, H=8, exchange="persistent/drop:7@2"),
                     A, b)


# ------------------------------------------------------------ stragglers
def test_straggler_barrier_factor_formulas():
    assert StragglerProfile().expected_barrier_mult(8) == 1.0
    assert StragglerProfile.parse("det(slow=16)").expected_barrier_mult(4) \
        == 16.0
    mix = StragglerProfile.parse("mix(p=0.5,slow=16)")
    assert mix.expected_barrier_mult(4) == pytest.approx(
        1 + 15 * (1 - 0.5 ** 4))  # 15.0625
    # more workers -> more likely someone straggles, monotone in K
    assert (mix.expected_barrier_mult(8) > mix.expected_barrier_mult(4)
            > mix.expected_barrier_mult(1) == 1 + 15 * 0.5)
    logn = StragglerProfile.parse("lognormal(sigma=0.5)")
    m4, m8 = logn.expected_barrier_mult(4), logn.expected_barrier_mult(8)
    assert 1.0 < m4 < m8 < 16.0
    # fixed-seed Monte Carlo: deterministic across calls
    assert logn.expected_barrier_mult(4) == m4
    with pytest.raises(ValueError, match="K >= 1"):
        logn.expected_barrier_mult(0)


def test_straggler_multipliers_deterministic_per_round_key():
    prof = StragglerProfile.parse("mix(p=0.5,slow=8)")
    key = jax.random.key(7)
    m1 = np.asarray(prof.multipliers(key, 8))
    assert m1.shape == (8,) and set(np.unique(m1)) <= {1.0, 8.0}
    assert np.array_equal(m1, np.asarray(prof.multipliers(key, 8)))
    assert not np.array_equal(
        m1, np.asarray(prof.multipliers(jax.random.key(8), 8)))
    det = np.asarray(StragglerProfile.parse("det(slow=3)")
                     .multipliers(key, 4))
    assert np.array_equal(det, [3.0, 1.0, 1.0, 1.0])
    bm = np.asarray(prof.barrier_mults(key, 8, rounds=5))
    assert bm.shape == (5,) and set(np.unique(bm)) <= {1.0, 8.0}


def test_straggler_profile_is_numerically_inert_in_the_driver():
    """The drivers' contract: under a bulk-synchronous barrier a
    straggler profile changes wall-clock only — bit-identical
    trajectory with and without it."""
    A, b, _ = make_glm_data(m=48, n=96, density=0.3, seed=1)
    finals = {}
    for spec in ("compressed:int8/stale",
                 "compressed:int8/stale/straggler:mix(p=0.5,slow=8)"):
        tr = CoCoATrainer(CoCoAConfig(K=4, H=16, seed=0, exchange=spec),
                          A, b)
        tr.run(4, record_every=4)
        finals[spec] = (np.asarray(tr.alpha_final), np.asarray(tr.w_final))
    (a0, w0), (a1, w1) = finals.values()
    assert np.array_equal(a0, a1) and np.array_equal(w0, w1)


# ------------------------------------------------------------ membership
def test_membership_masks_and_live_count():
    ms = MembershipSchedule.parse("drop:1@2-4/drop:3@5")
    assert ms.spec == "drop:1@2-4/drop:3@5"
    want = {1: [1, 1, 1, 1], 2: [1, 0, 1, 1], 4: [1, 0, 1, 1],
            5: [1, 1, 1, 0], 9: [1, 1, 1, 0]}
    for t, mask in want.items():
        assert np.array_equal(np.asarray(ms.live_mask(t, 4)), mask), t
        assert ms.live_count(t, 4) == sum(mask), t
    # open-ended drop: never rejoins
    forever = MembershipSchedule.parse("drop:0@3")
    assert forever.live_count(2, 4) == 4
    assert forever.live_count(100, 4) == 3
    with pytest.raises(ValueError, match="only K=2"):
        ms.check_workers(2)
    # the mask works under tracing (one compile serves every round)
    traced = jax.jit(lambda t: ms.live_mask(t, 4))
    assert np.array_equal(np.asarray(traced(2)), want[2])
    assert np.array_equal(np.asarray(traced(5)), want[5])


# ------------------------------- bounded staleness vs a serial replay
class _ToyAlgo:
    """Minimal RoundAlgorithm with round-index-dependent applies, so a
    queue slot applied under the wrong index (or dropped, or applied
    twice) shifts the final state detectably."""
    live_reweight = False

    def local_step(self, data_k, local_k, shared, key, t):
        upd = 0.5 * (data_k - shared)
        return upd, local_k + upd

    def apply_update(self, shared, total, t):
        return shared + total / (4.0 * t)

    def local_metric(self, data_k, local_k, shared_new):
        return jnp.sum((data_k - shared_new) ** 2)

    def finalize_metric(self, shared_new, metric_sum):
        return metric_sum


def _toy_replay(data, shared0, local0, rounds, k, membership):
    """Plain-Python reference of the bounded-stale contract: the
    aggregate computed in round t is applied in round t+k under index
    t (masked while no real aggregate reached the queue head), dropped
    workers contribute exact zero and keep their state frozen, and the
    post-run flush absorbs every still-pending aggregate."""
    K = data.shape[0]
    shared = shared0.astype(np.float64).copy()
    local = local0.astype(np.float64).copy()
    pending = [(np.zeros_like(shared), 0)] * k  # (aggregate, its round)
    for t in range(1, rounds + 1):
        mask = np.asarray(membership.live_mask(t, K))
        upd = 0.5 * (data - shared[None, :]) * mask[:, None]
        local = np.where(mask[:, None] > 0, local + upd, local)
        total = upd.sum(axis=0)
        agg, idx = pending[0]
        if idx >= 1:
            shared = shared + agg / (4.0 * idx)
        pending = pending[1:] + [(total, t)]
    for agg, idx in pending:
        if idx >= 1:
            shared = shared + agg / (4.0 * idx)
    return shared, local


@pytest.mark.parametrize("spec,k", [
    ("persistent/stale", 1),
    ("persistent/stale:k=2", 2),
    ("persistent/stale:k=3", 3),
    ("persistent/stale:k=2/drop:1@2-3", 2),
    ("persistent/stale:k=3/drop:0@1-2/drop:2@4", 3),
])
def test_bounded_stale_matches_serial_replay(spec, k):
    """Driver vs replay over a range of (rounds, k) shapes — including
    rounds < k (every slot flushed while still masked), rounds == k,
    and a worker dropping while its round-t aggregate is still in
    flight in the queue (the flush must still absorb it: no aggregate
    is silently lost)."""
    rng = np.random.default_rng(5)
    K, L = 4, 6
    data = rng.standard_normal((K, L)).astype(np.float32)
    shared0 = rng.standard_normal(L).astype(np.float32)
    local0 = np.zeros((K, L), np.float32)
    ex = ExchangeConfig.parse(spec)
    assert ex.mode.k == k
    algo = _ToyAlgo()
    for rounds in (1, k, k + 2, 7):
        rf = build_virtual_round(algo, ex, jnp.asarray(data), K=K)
        local = jnp.asarray(local0)
        shared = init_exchange_state(ex, jnp.asarray(shared0))
        for t in range(1, rounds + 1):
            local, shared, _ = rf(local, shared, jax.random.key(t), t)
        got = np.asarray(finish_run(rf, shared, rounds))
        want, want_local = _toy_replay(data, shared0, local0, rounds, k,
                                       ex.membership)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5,
                                   err_msg=f"{spec} rounds={rounds}")
        np.testing.assert_allclose(np.asarray(local), want_local,
                                   atol=1e-5, rtol=1e-5,
                                   err_msg=f"{spec} rounds={rounds} local")


def test_stale_k1_matches_pre_bounded_stale_pinned_trajectory():
    """``stale`` and ``stale:k=1`` are the same mode object — the
    bounded generalization must not have changed k=1's behaviour."""
    assert ExchangeMode.parse("stale") == ExchangeMode.parse("stale:k=1")
    A, b, _ = make_glm_data(m=48, n=96, density=0.3, seed=1)
    finals = {}
    for spec in ("persistent/stale", "persistent/stale:k=1"):
        tr = CoCoATrainer(CoCoAConfig(K=4, H=16, seed=0, exchange=spec),
                          A, b)
        tr.run(5, record_every=5)
        finals[spec] = np.asarray(tr.alpha_final)
    a, b_ = finals.values()
    assert np.array_equal(a, b_)
