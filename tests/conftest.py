import os
import sys

# Tests see ONE device (the dry-run fakes 512 in its own subprocess only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
