import os
import sys

import pytest

# Tests see ONE device (the dry-run fakes 512 in its own subprocess only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# test-tier registry -> markers
# ---------------------------------------------------------------------------
# One place declares which tests belong to the `slow` tier (long
# subprocess/dry-run tests deselectable with -m "not slow"), mirroring
# the bench registry's tier table. The marker is applied at collection
# from this registry rather than by per-test decoration, so a renamed
# or newly added slow test cannot silently drift out of the tier — and
# a registry entry that stops matching ANY collected test fails loudly
# instead of rotting.
TEST_TIERS = {
    # nodeid substring -> tier
    "test_distributed.py::test_dryrun_production_mesh_smoke": "slow",
    "test_collectives.py::test_ring_sharded_trainer_matches_virtual": "slow",
    "test_dist_launch.py::test_two_process_matches_single": "slow",
    "test_analysis_cli.py::test_cli_clean_cells_write_json": "slow",
    "test_analysis_cli.py::test_cli_injected_violation_exits_nonzero": "slow",
    "test_analysis_cli.py::test_graph_extraction_per_transport": "slow",
}

_KNOWN_TIERS = ("slow",)


def pytest_collection_modifyitems(config, items):
    for tier in TEST_TIERS.values():
        assert tier in _KNOWN_TIERS, f"unknown test tier {tier!r}"
    unmatched = set(TEST_TIERS)
    for item in items:
        for pattern, tier in TEST_TIERS.items():
            if pattern in item.nodeid:
                item.add_marker(getattr(pytest.mark, tier))
                unmatched.discard(pattern)
    # a registry entry whose FILE was collected but whose test was not
    # points at a renamed/deleted test — fail loudly instead of letting
    # the tier silently shrink (entries whose file was not collected at
    # all are fine: a path/-k selection legitimately skips them, as
    # does selecting individual tests by node id, which narrows
    # collection within a file without anything being renamed).
    # Compare by basename: nodeids carry an invocation-dependent path
    # prefix ("tests/test_x.py" from the repo root, "test_x.py" from
    # inside tests/), registry entries do not.
    if any("::" in str(arg) for arg in config.args):
        return
    collected_files = {os.path.basename(item.nodeid.split("::")[0])
                       for item in items}
    stale_entries = [p for p in unmatched
                     if os.path.basename(p.split("::")[0])
                     in collected_files]
    if stale_entries:
        raise pytest.UsageError(
            f"test-tier registry entries matched no collected test: "
            f"{sorted(stale_entries)} — update tests/conftest.py")
