"""Framework-overhead model + H trade-off machinery (paper §5.2-§5.5)."""
import numpy as np
import pytest

# hypothesis is a dev extra (CI installs it via .[dev]); only the
# property-based test skips without it, not the whole module
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(**kw):  # noqa: D103 — placeholder so the decorator parses
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(**kw):
        return lambda f: f

    class st:  # noqa: D101
        floats = staticmethod(lambda *a, **k: None)

from repro.bench.timing import calibrate_link, synthetic_link
from repro.core.distributed import CommScheme
from repro.core.overheads import PROFILES, communicated_bytes_per_round
from repro.core.tradeoff import (HSweep, HSweepPoint, NoConvergedPointError,
                                 TimeModel, autotune_H, compute_fraction_at,
                                 optimal_H, time_to_eps)


def test_profile_calibration_matches_paper_ratios():
    A, B, C, D = (PROFILES["A_spark"], PROFILES["B_spark_c"],
                  PROFILES["C_pyspark"], PROFILES["D_pyspark_c"])
    Bo, Do, E = (PROFILES["B_spark_opt"], PROFILES["D_pyspark_opt"],
                 PROFILES["E_mpi"])
    # pySpark overheads ~15x Spark/Scala reference (paper Fig 3)
    assert abs(C.overhead_units / A.overhead_units - 15.0) < 1e-6
    # flat format: B = A/3
    assert abs(A.overhead_units / B.overhead_units - 3.0) < 1e-6
    # persistent+meta-RDD: B* = B/3, D* = D/10
    assert abs(B.overhead_units / Bo.overhead_units - 3.0) < 1e-6
    assert abs(D.overhead_units / Do.overhead_units - 10.0) < 1e-6
    # MPI overhead ~3% of total at H=n_local (compute 1 unit)
    frac = E.overhead_units / (E.compute_mult * 1.0 + E.overhead_units)
    assert 0.02 < frac < 0.04
    # C++ offload speedups: Scala ~10x, Python >100x
    assert 8 < A.compute_mult / B.compute_mult < 12
    assert C.compute_mult / D.compute_mult > 100


def test_round_time_and_compute_fraction():
    E = PROFILES["E_mpi"]
    t = E.round_time(t_solver_s=1.0, t_ref_s=1.0)
    assert abs(t - 1.031) < 1e-6
    assert E.compute_fraction(1.0, 1.0) > 0.9  # paper: MPI ~90%+ computing
    D = PROFILES["D_pyspark_c"]
    assert D.compute_fraction(1.0, 1.0) < 0.1


def test_communicated_bytes_persistent_vs_not():
    m, n, K = 1000, 100000, 8
    # every array in the system is float32: itemsize defaults to 4
    with_alpha = communicated_bytes_per_round(m, n, K, persistent_alpha=False)
    without = communicated_bytes_per_round(m, n, K, persistent_alpha=True)
    assert with_alpha - without == 2 * n * 4
    assert without == 2 * K * m * 4


def test_communicated_bytes_by_scheme():
    """The scheme-aware accounting matches the CommScheme dtypes: int8
    Delta v + 4-byte f32 scale per worker for `compressed`."""
    m, n, K = 1000, 100000, 8
    assert (communicated_bytes_per_round(m, n, K, True, scheme="persistent")
            == 2 * K * m * 4)
    assert (communicated_bytes_per_round(m, n, K, True, scheme="spark_faithful")
            == 2 * K * m * 4 + 2 * n * 4)
    assert (communicated_bytes_per_round(m, n, K, True, scheme="compressed")
            == 2 * K * (m + 4))
    # when K does not divide n, the scheme path counts the K zero-padded
    # ceil(n/K) blocks the collectives actually move
    assert (communicated_bytes_per_round(m, n + 1, K, True,
                                         scheme="spark_faithful")
            == 2 * K * m * 4 + 2 * ((n + 1 + K - 1) // K) * K * 4)
    with pytest.raises(ValueError, match="unknown comm scheme"):
        communicated_bytes_per_round(m, n, K, True, scheme="quantised")


def test_communicated_bytes_reduce_scatter():
    """The ring exchange moves 2*(K-1)/K of the (K-padded) vector per
    worker each way: 2*(K-1)*len_pad*4 bytes total, always below the
    master-centric persistent scheme's 2*K*len*4."""
    K = 8
    rs = CommScheme.parse("reduce_scatter")
    assert rs.bytes_per_round(1000, K) == 2 * (K - 1) * 1000 * 4
    # K does not divide the length: the padded vector is what moves
    assert rs.bytes_per_round(1001, K) == 2 * (K - 1) * 1008 * 4
    assert (rs.bytes_per_round(1000, K)
            < CommScheme.parse("persistent").bytes_per_round(1000, K))
    # the overheads-layer accounting agrees with the scheme
    assert (communicated_bytes_per_round(1000, 100000, K, True,
                                         scheme="reduce_scatter")
            == rs.bytes_per_round(1000, K))


# ------------------------------------------------------- bytes -> seconds
def test_time_model_monotone_in_bytes():
    """round_time must grow strictly with the charged traffic, and the
    increment must be exactly bytes/bandwidth (latency is per-round)."""
    link = synthetic_link(1e9, latency_s=1e-4)
    E = PROFILES["E_mpi"]
    ts = [TimeModel(E, b, link).round_time(1.0, 1.0)
          for b in (0, 1 << 10, 1 << 20, 1 << 30)]
    assert all(a < b for a, b in zip(ts, ts[1:]))
    m1 = TimeModel(E, 10 ** 9, link)
    # 1e9 bytes at 1 GB/s = 1 s on the wire, plus the 100 us latency
    assert m1.round_time(1.0, 1.0) == pytest.approx(
        E.round_time(1.0, 1.0) + 1.0 + 1e-4)
    assert m1.comm_time_s() == pytest.approx(1.0 + 1e-4)
    # no link, or nothing to move, degrades to the bare profile (no
    # latency charge either: zero modelled bytes means no collective)
    assert (TimeModel(E, 10 ** 9, None).round_time(1.0, 1.0)
            == E.round_time(1.0, 1.0))
    assert TimeModel(E, 0, link).round_time(1.0, 1.0) \
        == E.round_time(1.0, 1.0)


def test_time_model_scheme_ordering_fixed_H():
    """At fixed H (same measured compute) the model must rank schemes
    exactly as their modelled traffic: compressed < reduce_scatter <
    persistent < spark_faithful."""
    m, n_state, K = 1000, 4096, 8
    link = synthetic_link(1e9, latency_s=1e-4)
    E = PROFILES["E_mpi"]
    t = {s: TimeModel(E, CommScheme.parse(s).bytes_per_round(
            m, K, local_state_len=n_state), link).round_time(1.0, 1.0)
         for s in ("compressed", "reduce_scatter", "persistent",
                   "spark_faithful")}
    assert (t["compressed"] < t["reduce_scatter"] < t["persistent"]
            < t["spark_faithful"])


def test_time_model_stale_overlap_term():
    """The stale exchange mode hides min(t_comm, t_compute): the round
    only pays the overhang, a fully-hidden transfer costs nothing, and
    the link-level overlap argument does the same arithmetic."""
    link = synthetic_link(1e9, latency_s=1e-4)
    E = PROFILES["E_mpi"]
    nbytes = 10 ** 9  # 1 s on the wire (+ the 100 us latency)
    sync = TimeModel(E, nbytes, link)
    stale = TimeModel(E, nbytes, link, exchange="stale")
    t_solver = 0.25  # E_mpi compute_mult = 1 -> t_compute = 0.25 s
    t_wire = link.seconds_for(nbytes)
    hidden = min(t_wire, E.compute_mult * t_solver)
    assert stale.round_time(t_solver, 1.0) == pytest.approx(
        sync.round_time(t_solver, 1.0) - hidden)
    # fully hidden: compute >= wire -> bare profile time, not negative
    tiny = TimeModel(E, 10 ** 6, link, exchange="stale")  # ~1.1 ms wire
    assert tiny.round_time(1.0, 1.0) == E.round_time(1.0, 1.0)
    assert tiny.comm_time_s(t_compute_s=1.0) == 0.0
    # a k-deep pending queue hides behind k rounds of compute
    k2 = TimeModel(E, nbytes, link, exchange="stale:k=2")
    assert k2.comm_time_s(t_compute_s=0.4) == pytest.approx(
        max(t_wire - 0.8, 0.0))
    assert k2.round_time(t_solver, 1.0) <= stale.round_time(t_solver, 1.0)
    # the LinkCalibration primitive agrees
    assert link.seconds_for(nbytes, overlap_s=0.25) == pytest.approx(
        t_wire - 0.25)
    assert link.seconds_for(nbytes, overlap_s=10.0) == 0.0
    # sync ignores the compute term entirely
    assert sync.comm_time_s(t_compute_s=10.0) == pytest.approx(t_wire)
    # hiding can only help: stale round time never exceeds sync's
    for ts in (0.0, 0.1, 1.0, 10.0):
        assert (stale.round_time(ts, 1.0)
                <= sync.round_time(ts, 1.0) + 1e-12)
    with pytest.raises(ValueError, match="unknown exchange"):
        TimeModel(E, exchange="async")
    # the deprecated mode= knob still works — under a warning
    with pytest.warns(DeprecationWarning, match="TimeModel.mode"):
        old = TimeModel(E, nbytes, link, mode="stale")
    assert old.round_time(t_solver, 1.0) == stale.round_time(t_solver, 1.0)


def test_stale_mode_shifts_optimal_H_down_on_hideable_link():
    """The paper's staleness result in the time model: on a slow link
    whose wire time is hideable behind local compute, the stale overlap
    term moves the optimal H strictly DOWN (sync must amortize the
    constant wire term with big rounds; stale needn't) and time-to-eps
    improves."""
    sweep = _toy_sweep()
    sweep.comm_bytes_per_round = 10 ** 9
    link = synthetic_link(1e9)  # 1 s wire = compute at H=1024
    E = PROFILES["E_mpi"]
    h_sync, t_sync = optimal_H(TimeModel(E, link=link).for_sweep(sweep), sweep)
    stale_sweep = HSweep(eps=sweep.eps, n_local=sweep.n_local,
                         t_ref_s=sweep.t_ref_s, points=sweep.points,
                         mode="stale",
                         comm_bytes_per_round=sweep.comm_bytes_per_round)
    # the legacy display pair folds into the canonical spec
    assert stale_sweep.exchange == "persistent/stale"
    h_stale, t_stale = optimal_H(
        TimeModel(E, link=link).for_sweep(stale_sweep), stale_sweep)
    assert h_stale < h_sync, (h_stale, h_sync)
    assert t_stale < t_sync
    # for_sweep adopted the sweep's exchange (and with it the mode)
    assert TimeModel(E, link=link).for_sweep(stale_sweep).exchange.mode.stale
    assert not TimeModel(E, link=link).for_sweep(sweep).exchange.mode.stale


def test_straggler_barrier_shifts_optimal_H_down():
    """The straggler regime's trade, pinned deterministically: the
    barrier stretches ONLY the compute term (E[max over K workers] x
    t_solver), so per-round framework overhead is relatively cheaper
    and the optimum moves toward smaller H — the opposite direction of
    growing overhead."""
    sweep = _toy_sweep()
    D = PROFILES["D_pyspark_c"]  # overhead-heavy: the shift is visible
    base = TimeModel(D)
    strag = TimeModel(D, exchange="persistent/straggler:det(slow=64)",
                      workers=4)
    # det: worker 0 always runs slow x, so the barrier is exactly slow
    assert strag.barrier_mult == pytest.approx(64.0)
    h_base, _ = optimal_H(base, sweep)
    h_strag, _ = optimal_H(strag, sweep)
    assert h_strag < h_base, (h_strag, h_base)
    # mix barrier: 1 + (slow-1) * P(any of K straggles)
    mix = TimeModel(D, exchange="persistent/straggler:mix(p=0.5,slow=16)",
                    workers=4)
    assert mix.barrier_mult == pytest.approx(1 + 15 * (1 - 0.5 ** 4))
    # straggler slack counts as overhead, never as useful compute
    assert (strag.compute_fraction(1.0, 1.0)
            < base.compute_fraction(1.0, 1.0))
    # a straggler-bearing model must know K
    with pytest.raises(ValueError, match="workers"):
        TimeModel(D, exchange="persistent/straggler:det(slow=4)")


def test_calibrate_link_fake_bandwidth_deterministic():
    """The fake-bandwidth path runs no collectives: two calls return the
    identical synthetic calibration, byte for byte."""
    a = calibrate_link("persistent", fake_bandwidth_Bps=2e9,
                       fake_latency_s=1e-4)
    b = calibrate_link("spark_faithful", fake_bandwidth_Bps=2e9,
                       fake_latency_s=1e-4)
    assert a == b
    assert a.source == "synthetic"
    assert a.seconds_for(2e9) == pytest.approx(1.0 + 1e-4)
    # what-if scaling keeps latency, scales bandwidth
    slow = a.scaled(0.01)
    assert slow.bandwidth_Bps == pytest.approx(2e7)
    assert slow.latency_s == a.latency_s
    with pytest.raises(ValueError, match="bandwidth"):
        synthetic_link(0.0)


def test_calibrate_link_exchange_front_door_and_deprecated_scheme_name():
    """calibrate_link takes the unified exchange spec (backend segment
    included); the old ``scheme_name=`` keyword still works under one
    ReproDeprecationWarning, and disagreeing spellings are a hard
    error, not a silent preference."""
    from repro.utils.deprecation import ReproDeprecationWarning

    b = calibrate_link("persistent", fake_bandwidth_Bps=2e9,
                       fake_latency_s=1e-4)
    # non-default legacy value -> one warning (the default stays silent,
    # matching resolve_exchange everywhere else)
    with pytest.warns(ReproDeprecationWarning, match="comm_scheme"):
        a = calibrate_link(scheme_name="spark_faithful",
                           fake_bandwidth_Bps=2e9, fake_latency_s=1e-4)
    assert a == b   # synthetic path: same calibration either way
    # full specs parse through the front door (synthetic path ignores
    # the exchange, so the calibration is identical)
    assert calibrate_link("compressed:int4/ring", fake_bandwidth_Bps=2e9,
                          fake_latency_s=1e-4) == b
    with pytest.raises(ValueError, match="conflicts with deprecated"):
        calibrate_link("compressed:int4", scheme_name="persistent",
                       fake_bandwidth_Bps=2e9)


def test_time_model_ring_hop_latency():
    """The ring backend pays the link latency per HOP: 2(K-1) for the
    reduce-scatter+gather transports, K-1 for the gather-only
    (compressed) ones, against the fused fabric's single charge."""
    link = synthetic_link(1e9, latency_s=1e-3)
    E = PROFILES["E_mpi"]
    K, nbytes = 5, 10 ** 6      # 1 ms on the wire
    xla = TimeModel(E, nbytes, link, exchange="persistent", workers=K)
    ring = TimeModel(E, nbytes, link, exchange="persistent/ring",
                     workers=K)
    assert xla.comm_time_s() == pytest.approx(1e-3 + 1e-3)
    assert ring.comm_time_s() == pytest.approx(1e-3 + 2 * (K - 1) * 1e-3)
    gathered = TimeModel(E, nbytes, link,
                         exchange="compressed:int4/ring", workers=K)
    assert gathered.comm_time_s() == pytest.approx(
        1e-3 + (K - 1) * 1e-3)
    # hop count needs the ring size
    with pytest.raises(ValueError, match="needs workers=K"):
        TimeModel(E, nbytes, link, exchange="persistent/ring")


def test_ring_backend_shifts_optimal_H_up_on_latency_bound_link():
    """On a latency-dominated link the ring's 2(K-1) hop charges raise
    the per-round constant, so the optimum moves to BIGGER rounds —
    the same amortization trade the paper pins on framework overhead,
    now driven by the collective fabric."""
    link = synthetic_link(1e9, latency_s=0.2)
    E = PROFILES["E_mpi"]
    sweep = _toy_sweep()
    sweep.comm_bytes_per_round = 1 << 10    # tiny payload, pure latency
    h_xla, t_xla = optimal_H(
        TimeModel(E, link=link, workers=8).for_sweep(sweep), sweep)
    ring_sweep = HSweep(eps=sweep.eps, n_local=sweep.n_local,
                        t_ref_s=sweep.t_ref_s, points=sweep.points,
                        exchange="persistent/ring",
                        comm_bytes_per_round=sweep.comm_bytes_per_round)
    h_ring, t_ring = optimal_H(
        TimeModel(E, link=link, workers=8).for_sweep(ring_sweep),
        ring_sweep)
    assert h_ring > h_xla, (h_ring, h_xla)
    assert t_ring > t_xla   # the hops are a real cost, not a reshuffle


def _toy_sweep():
    """rounds_to_eps ~ c/H convergence; t_solver ~ linear in H."""
    sweep = HSweep(eps=1e-3, n_local=1024, t_ref_s=1.0)
    for H in (16, 64, 256, 1024, 4096):
        rounds = int(np.ceil(20000 / H)) + 5   # diminishing returns
        sweep.points.append(HSweepPoint(H, rounds, t_solver_s=H / 1024.0))
    return sweep


def test_optimal_H_grows_with_overhead():
    """The paper's core claim: optimal H shifts up as per-round overhead
    grows (Fig 6: >25x shift between implementations)."""
    sweep = _toy_sweep()
    h_mpi, _ = optimal_H(PROFILES["E_mpi"], sweep)
    h_spark, _ = optimal_H(PROFILES["B_spark_c"], sweep)
    h_pyspark, _ = optimal_H(PROFILES["D_pyspark_c"], sweep)
    assert h_mpi <= h_spark <= h_pyspark
    assert h_pyspark > h_mpi


def test_mistuned_H_costs_big():
    """Running MPI's optimal H on the pySpark profile (or vice versa)
    degrades time-to-eps (paper: 'would more than double its training
    time')."""
    sweep = _toy_sweep()
    h_mpi, t_mpi_at_own = optimal_H(PROFILES["E_mpi"], sweep)
    h_py, t_py_at_own = optimal_H(PROFILES["D_pyspark_c"], sweep)
    t_py_at_mpi_H = time_to_eps(
        PROFILES["D_pyspark_c"],
        next(p for p in sweep.points if p.H == h_mpi), sweep.t_ref_s)
    assert t_py_at_mpi_H > 1.5 * t_py_at_own


def test_compute_fraction_ordering_at_optimum():
    sweep = _toy_sweep()
    fr = {}
    for name in ("E_mpi", "B_spark_c", "D_pyspark_c"):
        h, _ = optimal_H(PROFILES[name], sweep)
        fr[name] = compute_fraction_at(PROFILES[name], sweep, h)
    # the optimal compute fraction decreases as overheads grow (Fig 7)
    assert fr["E_mpi"] >= fr["B_spark_c"] >= fr["D_pyspark_c"] - 1e-9


def test_optimal_H_shifts_up_as_bandwidth_decreases():
    """Acceptance criterion for the bytes/bandwidth term: a slower link
    makes every round more expensive, so the optimum moves toward fewer
    rounds (larger H) — the direction of the paper's >25x spread."""
    sweep = _toy_sweep()
    sweep.comm_bytes_per_round = 4 << 20  # 4 MiB of updates per round
    E = PROFILES["E_mpi"]
    fast = TimeModel(E, link=synthetic_link(100e9)).for_sweep(sweep)
    slow = TimeModel(E, link=synthetic_link(100e6)).for_sweep(sweep)
    h_fast, t_fast = optimal_H(fast, sweep)
    h_slow, t_slow = optimal_H(slow, sweep)
    assert h_slow > h_fast
    assert t_slow > t_fast
    # the comm term also eats into the compute fraction at fixed H
    assert (compute_fraction_at(slow, sweep, h_slow)
            < compute_fraction_at(fast, sweep, h_slow))


def test_optimal_H_raises_when_nothing_converges():
    """optimal_H raises a typed error instead of the old (None, inf)
    return that crashed every caller downstream on None arithmetic."""
    sweep = HSweep(eps=1e-9, n_local=64, t_ref_s=1.0, algorithm="cocoa",
                   scheme="persistent")
    for H in (4, 16):
        sweep.points.append(HSweepPoint(H, None, t_solver_s=0.1))
    with pytest.raises(NoConvergedPointError, match=r"no H in \[4, 16\]"):
        optimal_H(PROFILES["E_mpi"], sweep)
    try:
        optimal_H(PROFILES["E_mpi"], sweep)
    except NoConvergedPointError as e:
        assert e.sweep is sweep  # carries the sweep for diagnostics
    # a non-converged point is simply inf, not an error, in time_to_eps
    assert time_to_eps(PROFILES["E_mpi"], sweep.points[0],
                       sweep.t_ref_s) == float("inf")


def test_compute_fraction_at_unknown_H_is_informative():
    sweep = _toy_sweep()
    with pytest.raises(KeyError, match=r"H=3 is not a sweep grid point"):
        compute_fraction_at(PROFILES["E_mpi"], sweep, 3)


def test_autotune_H_boundary_optimum():
    """Regression: golden-section without endpoint evaluation misses a
    boundary optimum. Monotone-increasing cost must pin the low end,
    monotone-decreasing cost the high end."""
    lo, hi = 1, 4096
    # tiny overhead (the E_mpi regime): cost = 10 * (H + 0.001) grows in
    # H, so H* = lo — the old code could only return interior probes
    assert autotune_H(lambda H: 10, lambda H: H + 1e-3, lo, hi) == lo
    # pure c/H rounds with constant round time: cost falls in H -> hi
    assert autotune_H(lambda H: int(np.ceil(1e6 / H)) + 1,
                      lambda H: 1.0, lo, hi) == hi


@settings(max_examples=20, deadline=None)
@given(c=st.floats(100.0, 50000.0), slope=st.floats(1e-4, 1e-1),
       ovh=st.floats(1e-4, 10.0))
def test_autotune_H_finds_convex_minimum(c, slope, ovh):
    def rounds_fn(H):
        return int(np.ceil(c / H)) + 3

    def time_fn(H):
        return slope * H + ovh

    h = autotune_H(rounds_fn, time_fn, 1, 8192)
    cost_h = rounds_fn(h) * time_fn(h)
    # within 2x of grid optimum (golden section on noisy integer grid)
    grid = [2 ** i for i in range(14)]
    best = min(rounds_fn(g) * time_fn(g) for g in grid)
    assert cost_h <= 2.05 * best
