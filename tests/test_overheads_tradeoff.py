"""Framework-overhead model + H trade-off machinery (paper §5.2-§5.5)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev extra; CI installs it via .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.overheads import PROFILES, communicated_bytes_per_round
from repro.core.tradeoff import (HSweep, HSweepPoint, autotune_H,
                                 compute_fraction_at, optimal_H, time_to_eps)


def test_profile_calibration_matches_paper_ratios():
    A, B, C, D = (PROFILES["A_spark"], PROFILES["B_spark_c"],
                  PROFILES["C_pyspark"], PROFILES["D_pyspark_c"])
    Bo, Do, E = (PROFILES["B_spark_opt"], PROFILES["D_pyspark_opt"],
                 PROFILES["E_mpi"])
    # pySpark overheads ~15x Spark/Scala reference (paper Fig 3)
    assert abs(C.overhead_units / A.overhead_units - 15.0) < 1e-6
    # flat format: B = A/3
    assert abs(A.overhead_units / B.overhead_units - 3.0) < 1e-6
    # persistent+meta-RDD: B* = B/3, D* = D/10
    assert abs(B.overhead_units / Bo.overhead_units - 3.0) < 1e-6
    assert abs(D.overhead_units / Do.overhead_units - 10.0) < 1e-6
    # MPI overhead ~3% of total at H=n_local (compute 1 unit)
    frac = E.overhead_units / (E.compute_mult * 1.0 + E.overhead_units)
    assert 0.02 < frac < 0.04
    # C++ offload speedups: Scala ~10x, Python >100x
    assert 8 < A.compute_mult / B.compute_mult < 12
    assert C.compute_mult / D.compute_mult > 100


def test_round_time_and_compute_fraction():
    E = PROFILES["E_mpi"]
    t = E.round_time(t_solver_s=1.0, t_ref_s=1.0)
    assert abs(t - 1.031) < 1e-6
    assert E.compute_fraction(1.0, 1.0) > 0.9  # paper: MPI ~90%+ computing
    D = PROFILES["D_pyspark_c"]
    assert D.compute_fraction(1.0, 1.0) < 0.1


def test_communicated_bytes_persistent_vs_not():
    m, n, K = 1000, 100000, 8
    # every array in the system is float32: itemsize defaults to 4
    with_alpha = communicated_bytes_per_round(m, n, K, persistent_alpha=False)
    without = communicated_bytes_per_round(m, n, K, persistent_alpha=True)
    assert with_alpha - without == 2 * n * 4
    assert without == 2 * K * m * 4


def test_communicated_bytes_by_scheme():
    """The scheme-aware accounting matches the CommScheme dtypes: int8
    Delta v + 4-byte f32 scale per worker for `compressed`."""
    m, n, K = 1000, 100000, 8
    assert (communicated_bytes_per_round(m, n, K, True, scheme="persistent")
            == 2 * K * m * 4)
    assert (communicated_bytes_per_round(m, n, K, True, scheme="spark_faithful")
            == 2 * K * m * 4 + 2 * n * 4)
    assert (communicated_bytes_per_round(m, n, K, True, scheme="compressed")
            == 2 * K * (m + 4))
    # when K does not divide n, the scheme path counts the K zero-padded
    # ceil(n/K) blocks the collectives actually move
    assert (communicated_bytes_per_round(m, n + 1, K, True,
                                         scheme="spark_faithful")
            == 2 * K * m * 4 + 2 * ((n + 1 + K - 1) // K) * K * 4)
    with pytest.raises(ValueError, match="unknown comm scheme"):
        communicated_bytes_per_round(m, n, K, True, scheme="quantised")


def _toy_sweep():
    """rounds_to_eps ~ c/H convergence; t_solver ~ linear in H."""
    sweep = HSweep(eps=1e-3, n_local=1024, t_ref_s=1.0)
    for H in (16, 64, 256, 1024, 4096):
        rounds = int(np.ceil(20000 / H)) + 5   # diminishing returns
        sweep.points.append(HSweepPoint(H, rounds, t_solver_s=H / 1024.0))
    return sweep


def test_optimal_H_grows_with_overhead():
    """The paper's core claim: optimal H shifts up as per-round overhead
    grows (Fig 6: >25x shift between implementations)."""
    sweep = _toy_sweep()
    h_mpi, _ = optimal_H(PROFILES["E_mpi"], sweep)
    h_spark, _ = optimal_H(PROFILES["B_spark_c"], sweep)
    h_pyspark, _ = optimal_H(PROFILES["D_pyspark_c"], sweep)
    assert h_mpi <= h_spark <= h_pyspark
    assert h_pyspark > h_mpi


def test_mistuned_H_costs_big():
    """Running MPI's optimal H on the pySpark profile (or vice versa)
    degrades time-to-eps (paper: 'would more than double its training
    time')."""
    sweep = _toy_sweep()
    h_mpi, t_mpi_at_own = optimal_H(PROFILES["E_mpi"], sweep)
    h_py, t_py_at_own = optimal_H(PROFILES["D_pyspark_c"], sweep)
    t_py_at_mpi_H = time_to_eps(
        PROFILES["D_pyspark_c"],
        next(p for p in sweep.points if p.H == h_mpi), sweep.t_ref_s)
    assert t_py_at_mpi_H > 1.5 * t_py_at_own


def test_compute_fraction_ordering_at_optimum():
    sweep = _toy_sweep()
    fr = {}
    for name in ("E_mpi", "B_spark_c", "D_pyspark_c"):
        h, _ = optimal_H(PROFILES[name], sweep)
        fr[name] = compute_fraction_at(PROFILES[name], sweep, h)
    # the optimal compute fraction decreases as overheads grow (Fig 7)
    assert fr["E_mpi"] >= fr["B_spark_c"] >= fr["D_pyspark_c"] - 1e-9


@settings(max_examples=20, deadline=None)
@given(c=st.floats(100.0, 50000.0), slope=st.floats(1e-4, 1e-1),
       ovh=st.floats(1e-4, 10.0))
def test_autotune_H_finds_convex_minimum(c, slope, ovh):
    def rounds_fn(H):
        return int(np.ceil(c / H)) + 3

    def time_fn(H):
        return slope * H + ovh

    h = autotune_H(rounds_fn, time_fn, 1, 8192)
    cost_h = rounds_fn(h) * time_fn(h)
    # within 2x of grid optimum (golden section on noisy integer grid)
    grid = [2 ** i for i in range(14)]
    best = min(rounds_fn(g) * time_fn(g) for g in grid)
    assert cost_h <= 2.05 * best
