"""Flash attention (fwd + custom-vjp bwd) vs the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev extra; CI installs it via .[dev]
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


def _data(B, Sq, Skv, H, KV, D, Dv, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Skv, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Skv, KV, Dv)), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
    return q, k, v, qp, kp


def _ref(q, k, v, qp, kp, causal, window, scale, softcap=None):
    m = jnp.ones((q.shape[0], 1, q.shape[1], k.shape[1]), bool)
    if causal:
        m &= (qp[:, :, None] >= kp[:, None, :])[:, None]
    if window is not None:
        m &= (qp[:, :, None] - window < kp[:, None, :])[:, None]
    return L._attend_dense(q, k, v, m, scale, softcap)


@pytest.mark.parametrize("B,S,H,KV,D,qc,kc", [
    (1, 16, 4, 4, 8, 4, 4),
    (2, 37, 8, 4, 16, 16, 8),      # ragged + GQA
    (1, 64, 6, 2, 32, 64, 64),     # single chunk
    (3, 20, 4, 1, 8, 7, 5),        # MQA + non-divisible chunks
])
def test_flash_forward_matches_dense(B, S, H, KV, D, qc, kc):
    q, k, v, qp, kp = _data(B, S, S, H, KV, D, D, seed=S)
    o1 = _ref(q, k, v, qp, kp, True, None, D ** -0.5)
    o2 = L.flash_attention(q, k, v, q_pos=qp, kv_pos=kp, causal=True,
                           window=None, scale=D ** -0.5, q_chunk=qc,
                           kv_chunk=kc)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


def test_flash_grads_match_dense():
    q, k, v, qp, kp = _data(2, 33, 33, 8, 4, 16, 16, seed=1)
    ct = jnp.asarray(np.random.default_rng(2).standard_normal(
        (2, 33, 8, 16)), jnp.float32)

    def f_ref(q, k, v):
        return jnp.sum(_ref(q, k, v, qp, kp, True, None, 0.25) * ct)

    def f_fl(q, k, v):
        return jnp.sum(L.flash_attention(
            q, k, v, q_pos=qp, kv_pos=kp, causal=True, window=None,
            scale=0.25, q_chunk=8, kv_chunk=8) * ct)

    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_flash_window_and_softcap_grads():
    q, k, v, qp, kp = _data(1, 29, 29, 4, 2, 8, 8, seed=3)
    ct = jnp.ones((1, 29, 4, 8), jnp.float32)
    kw = dict(q_pos=qp, kv_pos=kp, causal=True, window=7, scale=0.3,
              q_chunk=8, kv_chunk=4, softcap=5.0)

    def f_fl(q, k, v):
        return jnp.sum(L.flash_attention(q, k, v, **kw) * ct)

    def f_ref(q, k, v):
        return jnp.sum(_ref(q, k, v, qp, kp, True, 7, 0.3, 5.0) * ct)

    np.testing.assert_allclose(f_ref(q, k, v), f_fl(q, k, v), rtol=1e-5)
    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_flash_mla_asymmetric_head_dims():
    """MLA uses D(qk)=48, Dv=32 — asymmetric dims must work."""
    q, k, v, qp, kp = _data(1, 24, 24, 4, 4, 48, 32, seed=4)
    o1 = _ref(q, k, v, qp, kp, True, None, 48 ** -0.5)
    o2 = L.flash_attention(q, k, v, q_pos=qp, kv_pos=kp, causal=True,
                           window=None, scale=48 ** -0.5, q_chunk=8,
                           kv_chunk=8)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 3), S=st.integers(2, 48),
    KV=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2, 3]),
    D=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    window=st.one_of(st.none(), st.integers(2, 16)),
    seed=st.integers(0, 1000),
)
def test_flash_property(B, S, KV, g, D, causal, window, seed):
    H = KV * g
    q, k, v, qp, kp = _data(B, S, S, H, KV, D, D, seed=seed)
    o1 = _ref(q, k, v, qp, kp, causal, window, D ** -0.5)
    o2 = L.flash_attention(q, k, v, q_pos=qp, kv_pos=kp, causal=causal,
                           window=window, scale=D ** -0.5,
                           q_chunk=16, kv_chunk=8)
    if not causal and window is None:
        pass  # fully dense rows — still fine
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
