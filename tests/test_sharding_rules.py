"""Sharding-rule unit tests (pure: a 1-device (1,1) mesh carries the
axis names; specs are data, no lowering happens)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, input_specs, SHAPES
from repro.configs.base import padded_vocab
from repro.launch import sharding as sh
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh: full production shape without needing 256 devices —
    # the spec functions only read mesh.shape / axis_names.
    from repro.utils.compat import abstract_mesh
    return abstract_mesh((16, 16), ("data", "model"))


def _params(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    return cfg, jax.eval_shape(lambda k: model.init(k), jax.random.key(0))


def test_vocab_padding():
    assert padded_vocab(get_config("whisper-tiny")) == 51968
    assert padded_vocab(get_config("mamba2-2.7b")) == 50432
    assert padded_vocab(get_config("tinyllama-1.1b")) == 32000
    for arch in ("llama4-maverick-400b-a17b", "deepseek-v3-671b"):
        assert padded_vocab(get_config(arch)) % 16 == 0


def test_untied_embed_sharded_on_feature_dim(mesh):
    _, params = _params("tinyllama-1.1b")       # untied
    specs = sh.param_specs(params, mesh)
    assert specs["embed"] == P(None, "model")
    assert specs["unembed"] == P(None, "model")


def test_tied_embed_keeps_vocab_sharding(mesh):
    _, params = _params("command-r-35b")        # tied
    assert "unembed" not in params
    specs = sh.param_specs(params, mesh)
    assert specs["embed"] == P("model", None)


def test_col_row_rules_on_stacked_layers(mesh):
    _, params = _params("tinyllama-1.1b")
    specs = sh.param_specs(params, mesh)
    layer = specs["stack"][0]
    # stacked params get a leading None for the layer-cycle dim
    assert layer["mixer"]["wq"]["w"] == P(None, None, "model")
    assert layer["mixer"]["wo"]["w"] == P(None, "model", None)
    assert layer["channel"]["w_up"]["w"] == P(None, None, "model")
    assert layer["channel"]["w_down"]["w"] == P(None, "model", None)
    # norms replicated
    assert layer["mixer_norm"]["scale"] == P(None, None)


def test_moe_expert_parallel_rule(mesh):
    _, params = _params("llama4-maverick-400b-a17b")
    specs = sh.param_specs(params, mesh)
    layer = specs["stack"][0]
    assert layer["channel"]["w_up"] == P(None, "model", None, None)
    assert layer["channel"]["w_down"] == P(None, "model", None, None)
    assert layer["channel"]["router"]["w"] == P(None, None, None)


def test_fsdp_adds_data_axis(mesh):
    _, params = _params("qwen2-vl-72b")
    specs = sh.param_specs(params, mesh, fsdp=True)
    w = specs["stack"][0]["mixer"]["wq"]["w"]
    assert "data" in jax.tree.leaves(tuple(w), is_leaf=lambda x: True) \
        or w == P(None, "data", "model")


def test_batch_specs_shard_leading_dim(mesh):
    cfg = get_config("tinyllama-1.1b")
    batch = input_specs(cfg, SHAPES["train_4k"])
    specs = sh.batch_specs(batch, mesh)
    assert specs["tokens"][0] in ("data", ("data",))
    # batch=1 long-context tokens stay replicated
    b1 = {"t": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    s1 = sh.batch_specs(b1, mesh)
    assert s1["t"] == P(None, None)


def test_state_specs_cache_rules(mesh):
    cfg = get_config("tinyllama-1.1b")
    model = build_model(cfg)
    states = jax.eval_shape(lambda: model.init_states(None, 128, 32768))
    specs = sh.state_specs(states, mesh)
    k_spec = specs[0]["k"]
    assert k_spec[0] in ("data", ("data",)) and k_spec[1] == "model"
    # B=1 long context: sequence over everything
    states1 = jax.eval_shape(lambda: model.init_states(None, 1, 524288))
    specs1 = sh.state_specs(states1, mesh)
    assert specs1[0]["k"][1] == ("data", "model")
