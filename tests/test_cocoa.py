"""CoCoA core: convergence, equivalence, partitioning, baselines."""
import numpy as np
import pytest

from repro.core import COMM_SCHEMES, CoCoAConfig, CoCoATrainer
from repro.core.baselines import MinibatchSCD, MinibatchSGD, SGDConfig
from repro.core.glm import GLMProblem, optimal_objective, primal_objective, ridge_exact
from repro.core import partition as pt
from repro.data import make_glm_data

import jax.numpy as jnp


@pytest.fixture(scope="module")
def problem_data():
    return make_glm_data(m=256, n=512, density=0.25, seed=3)


def test_cocoa_converges_to_ridge_solution(problem_data):
    A, b, _ = problem_data
    cfg = CoCoAConfig(K=8, H=256, lam=1.0, eta=1.0)
    tr = CoCoATrainer(cfg, A, b)
    hist = tr.run(rounds=80, record_every=5, target_eps=1e-6)
    assert hist.subopt[-1] <= 1e-6
    alpha_star = ridge_exact(A, b, 1.0)
    rel = np.linalg.norm(tr.alpha_final - alpha_star) / np.linalg.norm(alpha_star)
    assert rel < 5e-3


def test_cocoa_elastic_net_converges(problem_data):
    A, b, _ = problem_data
    cfg = CoCoAConfig(K=4, H=256, lam=2.0, eta=0.5)
    tr = CoCoATrainer(cfg, A, b)
    hist = tr.run(rounds=150, record_every=10, target_eps=1e-4)
    assert hist.subopt[-1] <= 1e-4
    # sparsity from the l1 part
    assert (np.abs(tr.alpha_final) < 1e-8).mean() > 0.05


def test_suboptimality_monotone_trend(problem_data):
    A, b, _ = problem_data
    tr = CoCoATrainer(CoCoAConfig(K=8, H=128), A, b)
    hist = tr.run(rounds=40, record_every=1)
    s = np.array(hist.subopt)
    # overall decreasing (allow tiny numeric jitter)
    assert s[-1] < s[0] * 1e-1
    assert np.all(s[1:] <= s[:-1] + 1e-6)


def test_larger_H_fewer_rounds(problem_data):
    A, b, _ = problem_data
    rounds = {}
    for H in (32, 512):
        tr = CoCoATrainer(CoCoAConfig(K=8, H=H, seed=1), A, b)
        hist = tr.run(rounds=400, record_every=1, target_eps=1e-3)
        rounds[H] = hist.rounds_to(1e-3)
    assert rounds[512] is not None and rounds[32] is not None
    assert rounds[512] < rounds[32]


def test_minibatch_scd_slower_than_cocoa(problem_data):
    """CoCoA's immediate local updates beat fixed-residual mini-batch SCD
    round-for-round (the paper's motivation for choosing CoCoA)."""
    A, b, _ = problem_data
    coc = CoCoATrainer(CoCoAConfig(K=8, H=256, solver="scd_ref"), A, b)
    mb = CoCoATrainer(CoCoAConfig(K=8, H=256, solver="scd_fixed"), A, b)
    h1 = coc.run(rounds=60, record_every=60)
    h2 = mb.run(rounds=60, record_every=60)
    assert h1.subopt[-1] < h2.subopt[-1]


def test_minibatch_scd_first_class_converges(problem_data):
    """MinibatchSCD forces the fixed-residual solver and, with the
    1/sigma damping applied consistently to alpha AND Delta v, actually
    converges to the ridge solution (slower than CoCoA, but it gets
    there — the §2.1 baseline is a real algorithm, not a strawman)."""
    A, b, _ = problem_data
    mb = MinibatchSCD(CoCoAConfig(K=8, H=256, solver="scd_ref"), A, b)
    assert mb.cfg.solver == "scd_fixed"  # promoted, not trusted
    hist = mb.run(rounds=300, record_every=10, target_eps=1e-3)
    assert hist.subopt[-1] <= 1e-3
    # the residual invariant w = A alpha - b survives the damping:
    # recomputing the objective from alpha_final matches the trace
    assert abs(mb.objective_of(mb.alpha_final) - hist.primal[-1]) < 1e-2


def test_config_rejects_unknown_comm_scheme():
    """A typo'd scheme must raise, not silently run persistent."""
    with pytest.raises(ValueError, match="unknown exchange spec segment"):
        CoCoAConfig(exchange="persistant")
    with pytest.raises(ValueError, match="unknown exchange spec segment"):
        SGDConfig(exchange="spark")
    for scheme in COMM_SCHEMES:  # the real set all validate
        CoCoAConfig(exchange=scheme)
    # the deprecated comm_scheme= spelling still works — under a warning
    with pytest.warns(DeprecationWarning, match="comm_scheme"):
        cfg = CoCoAConfig(comm_scheme="compressed")
    assert cfg.exchange.scheme.codec.name == "int8"
    # and a typo through the deprecated spelling still raises
    with pytest.raises(ValueError, match="unknown comm scheme"):
        CoCoAConfig(comm_scheme="persistant")


def test_comm_bytes_match_scheme_dtypes(problem_data):
    """Modelled per-round traffic is sized to the dtypes the collectives
    move: f32 updates (4B) for persistent/spark_faithful, int8 + a
    4-byte scale for compressed; spark_faithful adds the alpha blocks."""
    A, b, _ = problem_data
    m, n, K = A.shape[0], A.shape[1], 8
    by = {s: CoCoATrainer(CoCoAConfig(K=K, exchange=s), A, b)
          for s in COMM_SCHEMES}
    n_pad = by["persistent"].part.n_padded
    assert by["persistent"].comm_bytes_per_round() == 2 * K * m * 4
    assert (by["spark_faithful"].comm_bytes_per_round()
            == 2 * K * m * 4 + 2 * K * n_pad * 4)
    assert by["compressed"].comm_bytes_per_round() == 2 * K * (m + 4)
    # codec-composed schemes: the transport is priced per wire codec
    int4 = CoCoATrainer(CoCoAConfig(K=K, exchange="compressed:int4"),
                        A, b)
    assert int4.comm_bytes_per_round() == 2 * K * (-(-m // 2) + 4)
    sgd = {s: MinibatchSGD(SGDConfig(K=K, exchange=s), A, b)
           for s in COMM_SCHEMES}
    assert sgd["persistent"].comm_bytes_per_round() == 2 * K * n * 4
    assert sgd["compressed"].comm_bytes_per_round() == 2 * K * (n + 4)


def test_mllib_style_sgd_much_slower(problem_data):
    A, b, _ = problem_data
    p_star = optimal_objective(GLMProblem(1.0, 1.0), A, b)
    tr = CoCoATrainer(CoCoAConfig(K=8, H=256), A, b)
    hist = tr.run(rounds=40, record_every=40)
    sgd = MinibatchSGD(SGDConfig(batch_frac=0.5, step_size=1e-3, lam=1.0), A, b)
    hist2 = sgd.run(40, p_star=p_star, p_zero=tr.p_zero, record_every=40)
    assert hist.subopt[-1] < hist2.subopt[-1]


def test_balanced_partitioner_beats_block():
    A, b, _ = make_glm_data(m=128, n=400, density=0.15, zipf_a=1.05, seed=7)
    nnz = (np.abs(A) > 0).sum(axis=0)
    bal = pt.balanced_partition(nnz, 8)
    blk = pt.block_partition(400, 8)
    assert pt.partition_imbalance(bal, nnz) <= pt.partition_imbalance(blk, nnz)
    assert pt.partition_imbalance(bal, nnz) < 1.05


def test_pack_unpack_roundtrip():
    A, b, _ = make_glm_data(m=64, n=100, seed=0)
    part = pt.balanced_partition((np.abs(A) > 0).sum(0), 4)
    packed, mask = pt.pack_columns(A, part)
    assert packed.shape[0] == 4 and mask.shape == packed.shape[::2]
    # scatter alpha back
    alpha_st = np.arange(4 * part.n_padded, dtype=np.float32).reshape(4, -1)
    alpha_st *= mask
    alpha = pt.unpack_alpha(alpha_st, part, 100)
    for k, ids in enumerate(part.owned):
        np.testing.assert_allclose(alpha[ids], alpha_st[k, : len(ids)])


def test_objective_primal_from_state_matches(problem_data):
    A, b, _ = problem_data
    from repro.core.glm import primal_from_state
    prob = GLMProblem(1.0, 0.7)
    alpha = np.random.default_rng(0).standard_normal(A.shape[1]).astype(np.float32)
    w = A @ alpha - b
    p1 = primal_objective(prob, jnp.asarray(A), jnp.asarray(b), jnp.asarray(alpha))
    p2 = primal_from_state(prob, jnp.asarray(w), prob.regularizer(jnp.asarray(alpha)))
    assert abs(float(p1) - float(p2)) < 1e-2


def test_compressed_communication_converges(problem_data):
    """Beyond-paper: int8-quantized Delta-v exchange (4x less traffic)
    must not break CoCoA's convergence (inexact local solutions are
    within the framework's tolerance)."""
    A, b, _ = problem_data
    tr = CoCoATrainer(CoCoAConfig(K=8, H=256, exchange="compressed"),
                      A, b)
    hist = tr.run(rounds=120, record_every=10, target_eps=1e-3)
    assert hist.subopt[-1] <= 1e-3
