"""Pallas SCD kernel vs the pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; CI installs it via .[dev]
from hypothesis import given, settings, strategies as st

from repro.kernels import scd_steps_kernel, scd_steps_ref


def _mk(m, n, H, dtype, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((m, n)), dtype)
    colsq = jnp.sum(A.astype(jnp.float32) ** 2, axis=0)
    alpha = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal(m), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, H), jnp.int32)
    return A.astype(jnp.float32), colsq, alpha, w, idx


@pytest.mark.parametrize("m,n,H,h_blk", [
    (32, 16, 8, 8), (64, 64, 64, 16), (128, 96, 200, 64),
    (256, 17, 7, 128), (512, 128, 333, 100), (33, 5, 1, 4),
])
def test_kernel_matches_oracle_shapes(m, n, H, h_blk):
    A, colsq, alpha, w, idx = _mk(m, n, H, jnp.float32, seed=m + n + H)
    kw = dict(sigma=8.0, lam=1.0, eta=1.0)
    dv_r, a_r = scd_steps_ref(A, colsq, alpha, w, idx, **kw)
    dv_k, a_k = scd_steps_kernel(A, colsq, alpha, w, idx, h_blk=h_blk, **kw)
    np.testing.assert_allclose(dv_r, dv_k, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a_r, a_k, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("eta", [0.0, 0.3, 1.0])
def test_kernel_matches_oracle_elastic_net(eta):
    A, colsq, alpha, w, idx = _mk(96, 48, 120, jnp.float32, seed=11)
    kw = dict(sigma=4.0, lam=2.5, eta=eta)
    dv_r, a_r = scd_steps_ref(A, colsq, alpha, w, idx, **kw)
    dv_k, a_k = scd_steps_kernel(A, colsq, alpha, w, idx, **kw)
    np.testing.assert_allclose(dv_r, dv_k, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a_r, a_k, rtol=1e-4, atol=1e-5)


def test_kernel_bf16_stream_close_to_f32_oracle():
    """bf16 column streaming with f32 accumulation stays near the oracle."""
    rng = np.random.default_rng(5)
    m, n, H = 128, 64, 96
    A32 = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    Abf = A32.astype(jnp.bfloat16).astype(jnp.float32)  # quantized data
    colsq = jnp.sum(Abf ** 2, axis=0)
    alpha = jnp.zeros(n, jnp.float32)
    w = jnp.asarray(rng.standard_normal(m), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, H), jnp.int32)
    kw = dict(sigma=8.0, lam=1.0, eta=1.0)
    dv_r, a_r = scd_steps_ref(Abf, colsq, alpha, w, idx, **kw)
    dv_k, a_k = scd_steps_kernel(Abf, colsq, alpha, w, idx, **kw)
    np.testing.assert_allclose(dv_r, dv_k, rtol=1e-4, atol=1e-4)


def test_kernel_duplicate_indices_sequential_semantics():
    """Visiting the same coordinate twice must apply updates sequentially."""
    A, colsq, alpha, w, _ = _mk(64, 8, 0, jnp.float32, seed=2)
    idx = jnp.asarray([3, 3, 3, 5, 3, 5], jnp.int32)
    kw = dict(sigma=2.0, lam=0.5, eta=0.8)
    dv_r, a_r = scd_steps_ref(A, colsq, alpha, w, idx, **kw)
    dv_k, a_k = scd_steps_kernel(A, colsq, alpha, w, idx, h_blk=4, **kw)
    np.testing.assert_allclose(dv_r, dv_k, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a_r, a_k, rtol=1e-5, atol=1e-6)


def test_kernel_zero_column_noop():
    """Padded (all-zero) columns must leave state untouched."""
    A, colsq, alpha, w, _ = _mk(32, 6, 0, jnp.float32, seed=3)
    A = A.at[:, 2].set(0.0)
    colsq = colsq.at[2].set(0.0)
    idx = jnp.asarray([2, 2, 2], jnp.int32)
    dv, a_new = scd_steps_kernel(A, colsq, alpha, w, idx,
                                 sigma=2.0, lam=1.0, eta=1.0)
    np.testing.assert_allclose(dv, np.zeros(32), atol=1e-7)
    np.testing.assert_allclose(a_new, alpha, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(8, 96),
    n=st.integers(2, 48),
    H=st.integers(1, 150),
    sigma=st.floats(1.0, 16.0),
    lam=st.floats(0.1, 4.0),
    eta=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_kernel_oracle_property(m, n, H, sigma, lam, eta, seed):
    A, colsq, alpha, w, idx = _mk(m, n, H, jnp.float32, seed=seed)
    kw = dict(sigma=sigma, lam=lam, eta=eta)
    dv_r, a_r = scd_steps_ref(A, colsq, alpha, w, idx, **kw)
    dv_k, a_k = scd_steps_kernel(A, colsq, alpha, w, idx, h_blk=32, **kw)
    np.testing.assert_allclose(dv_r, dv_k, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(a_r, a_k, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), H=st.integers(1, 64))
def test_scd_decreases_subproblem_objective(seed, H):
    """Each SCD epoch must not increase the local subproblem objective
    G_k(dalpha) = w.A da + sigma/2 ||A da||^2 + reg(alpha+da) - reg(alpha)."""
    rng = np.random.default_rng(seed)
    m, n, sigma, lam, eta = 48, 24, 4.0, 1.0, 0.7
    A = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    colsq = jnp.sum(A * A, 0)
    alpha0 = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal(m), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, H), jnp.int32)
    dv, alpha1 = scd_steps_ref(A, colsq, alpha0, w, idx,
                               sigma=sigma, lam=lam, eta=eta)

    def G(alpha):
        da = alpha - alpha0
        Ada = A @ da
        reg = lam * (eta / 2 * jnp.sum(alpha ** 2)
                     + (1 - eta) * jnp.sum(jnp.abs(alpha)))
        return float(w @ Ada + sigma / 2 * Ada @ Ada + reg)

    assert G(np.asarray(alpha1)) <= G(np.asarray(alpha0)) + 1e-4
