"""Optimizer + schedules + local-update (H-knob) properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev extra; CI installs it via .[dev]
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, LocalUpdatesConfig, adamw_init,
                         adamw_update, cosine_schedule, local_updates_round)
from repro.optim.local_updates import suggest_H


def test_adamw_first_step_matches_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0]])}
    g = {"w": jnp.asarray([[0.5, -0.5]])}
    st0 = adamw_init(p, cfg)
    p1, st1, _ = adamw_update(p, g, st0, cfg, 1.0)
    # bias-corrected first step = lr * sign-ish step g/|g|
    expected = p["w"] - 0.1 * g["w"] / (jnp.abs(g["w"]) + 1e-8)
    np.testing.assert_allclose(p1["w"], expected, rtol=1e-4)
    assert int(st1["count"]) == 1


def test_adamw_weight_decay_skips_1d():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    p1, _, _ = adamw_update(p, g, adamw_init(p, cfg), cfg, 1.0)
    assert float(jnp.max(p1["w"])) < 1.0      # decayed
    np.testing.assert_allclose(p1["b"], p["b"])  # not decayed


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(p, g, adamw_init(p, cfg), cfg, 1.0)
    assert float(m["grad_norm"]) > 100.0  # reported pre-clip


def test_quadratic_convergence():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(p, cfg)
    for _ in range(400):
        g = {"w": 2 * p["w"]}
        p, state, _ = adamw_update(p, g, state, cfg, 1.0)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05


def test_cosine_schedule_shape():
    s = [float(cosine_schedule(t, warmup=10, total=100)) for t in range(101)]
    assert s[0] == 0.0 and abs(s[10] - 1.0) < 1e-5
    assert all(a >= b - 1e-6 for a, b in zip(s[10:], s[11:]))  # decreasing
    assert s[100] >= 0.099  # min_frac floor


def test_local_updates_delta_vs_params_identical():
    """'delta' and 'params' averaging must produce identical results for
    any step function (algebraic identity)."""
    def step_fn(p, o, b):
        return jax.tree.map(lambda x: x - 0.1 * b, p), o, {}

    p0 = {"w": jnp.asarray([1.0, 2.0])}
    batches = jnp.asarray([0.5, 1.5, 1.0])
    outs = {}
    for avg in ("delta", "params"):
        cfg = LocalUpdatesConfig(H=3, average=avg)
        # axis_name=None -> no collective; compare the local math
        p1, _, _ = local_updates_round(step_fn, p0, {}, batches, cfg, None)
        outs[avg] = p1["w"]
    np.testing.assert_allclose(outs["delta"], outs["params"])


def test_local_updates_runs_H_steps():
    def step_fn(p, o, b):
        return jax.tree.map(lambda x: x + 1.0, p), o, {"v": p["w"][0]}

    p0 = {"w": jnp.zeros((2,))}
    batches = jnp.zeros((5,))
    p1, _, ms = local_updates_round(step_fn, p0, {}, batches,
                                    LocalUpdatesConfig(H=5), None)
    np.testing.assert_allclose(p1["w"], 5.0)
    assert ms["v"].shape == (5,)


@settings(max_examples=25, deadline=None)
@given(t_comp=st.floats(1e-4, 1.0), t_coll=st.floats(1e-5, 10.0))
def test_suggest_H_monotone_in_collective_cost(t_comp, t_coll):
    h1 = suggest_H(t_comp, t_coll)
    h2 = suggest_H(t_comp, t_coll * 4.0)
    assert h2 >= h1 >= 1
    assert h1 <= 64


def test_suggest_H_paper_regimes():
    # MPI-like: negligible comm -> H=1 (communicate every step)
    assert suggest_H(1.0, 0.01) == 1
    # Spark-like: comm 10x compute -> large H
    assert suggest_H(0.1, 1.0) >= 8
