"""Data pipeline, checkpointing, HLO parser, serving utilities."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import SHAPES, get_config, input_specs
from repro.data.tokens import TokenStream
from repro.utils.hlo import collective_bytes, parse_collectives


def test_token_stream_deterministic():
    a = TokenStream(1000, 64, 4, seed=7).next_batch()
    b = TokenStream(1000, 64, 4, seed=7).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_token_stream_learnable_structure():
    ts = TokenStream(500, 256, 2, seed=0, markov=0.8, period=16)
    b = ts.next_batch()["tokens"]
    rep = (b[:, 16:] == b[:, :-16]).mean()
    assert rep > 0.5  # repetition structure present


def test_token_labels_shifted():
    b = TokenStream(100, 32, 2, seed=1).next_batch()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()


def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.asarray(np.random.randn(4, 3), jnp.bfloat16),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                   "c": [jnp.ones((2,), jnp.float32), jnp.zeros((1,))]},
    }
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, tree, step=42)
    tree2, step = restore_checkpoint(path, tree)
    assert step == 42
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(tree2)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_hlo_parser_synthetic():
    txt = """
  %x = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8]
  %ag = f32[1024,256]{1,0} all-gather(%ar), dimensions={0}
  %rs = bf16[16,256]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[8]{0} collective-permute(%small)
  %small = f32[8]{0} parameter(1)
  %aa = f32[128,256]{1,0} all-to-all(%ar), dimensions={0}
"""
    stats = parse_collectives(txt)
    assert stats.by_kind["all-reduce"][0] == 1
    assert stats.by_kind["all-reduce"][1] == 128 * 256 * 4
    assert stats.by_kind["all-gather"][2] == 1024 * 256 * 4   # result bytes
    assert stats.by_kind["reduce-scatter"][1] == 128 * 256 * 4
    assert stats.by_kind["all-to-all"][0] == 1
    assert stats.total_count == 5
    assert collective_bytes(txt) == stats.total_operand_bytes


def test_hlo_parser_on_real_module():
    """all-reduce must be detected in a real psum lowering."""
    import numpy as _np

    def f(x):
        return x * 2 + 1

    txt = jax.jit(f).lower(jnp.ones((8, 8))).compile().as_text()
    stats = parse_collectives(txt)
    assert stats.total_count == 0  # no collectives on 1 device


def test_input_specs_cover_all_shapes():
    for arch in ("tinyllama-1.1b", "qwen2-vl-72b", "whisper-tiny",
                 "mamba2-2.7b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.kind == "train":
                assert "labels" in specs
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
                assert "positions" in specs
            if cfg.family == "audio":
                assert "frame_embeds" in specs
            if cfg.family == "vlm" and shape.kind != "decode":
                assert "patch_embeds" in specs


def test_greedy_generate_runs():
    from repro.serve import greedy_generate
    from repro.models import build_model
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out = greedy_generate(model, params, prompt, max_new=6)
    assert out.shape == (1, 6)
    assert bool(jnp.all((out >= 0) & (out < 512)))


def test_greedy_generate_small_max_new():
    """The max_new contract at the boundary: 0 emits NO tokens (it used
    to emit the prefill argmax anyway), 1 emits exactly the prefill
    argmax and agrees with the first token of a longer generation."""
    from repro.serve import greedy_generate
    from repro.models import build_model
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out0 = greedy_generate(model, params, prompt, max_new=0)
    assert out0.shape == (1, 0)
    assert out0.dtype == jnp.int32
    out1 = greedy_generate(model, params, prompt, max_new=1)
    assert out1.shape == (1, 1)
    out6 = greedy_generate(model, params, prompt, max_new=6)
    assert jnp.array_equal(out1, out6[:, :1])
