"""End-to-end tests for `python -m repro.analysis` and the per-transport
collective-graph extraction (subprocesses with faked CPU devices — slow
tier, see conftest.TEST_TIERS)."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cli(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)  # the CLI fakes its own devices
    return subprocess.run([sys.executable, "-m", "repro.analysis"] + args,
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


def _run(py: str, ndev: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_cli_clean_cells_write_json(tmp_path):
    out_json = tmp_path / "ANALYSIS.json"
    r = _cli(["--cells",
              "cocoa=persistent,minibatch_sgd=spark_faithful,"
              "cocoa=compressed:int8",
              "--out", str(out_json)])
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    report = json.loads(out_json.read_text())
    assert set(report) == {"cells", "rules", "findings", "summary"}
    assert report["summary"]["cells"] == 3
    assert report["summary"]["error"] == 0
    ids = {c["cell"] for c in report["cells"]}
    assert ids == {"cocoa=persistent", "minibatch_sgd=spark_faithful",
                   "cocoa=compressed:int8"}
    assert all(c["collectives"] >= 2 for c in report["cells"])
    rules = {r["id"] for r in report["rules"]}
    assert {"bytes-match", "wire-dtype", "ring-topology",
            "membership-invariant", "f32-intermediate", "single-compile",
            "jit-module-array", "deprecated-spelling"} <= rules
    # the int8 cell compiles CLEAN of the gather-side decode finding —
    # the fused decode+reduce path; any reappearance is an error now —
    # and the source lint over src/repro stays clean too
    assert not any(f["rule"] == "f32-intermediate"
                   for f in report["findings"])
    assert all(f["severity"] != "error" for f in report["findings"])


def test_cli_injected_violation_exits_nonzero(tmp_path):
    out_json = tmp_path / "ANALYSIS.json"
    r = _cli(["--cells", "cocoa=persistent", "--inject", "wire-f32",
              "--no-source-lint", "--out", str(out_json)])
    assert r.returncode == 1, r.stdout + "\n" + r.stderr
    report = json.loads(out_json.read_text())
    errs = [f for f in report["findings"] if f["severity"] == "error"]
    assert errs, report["findings"]
    assert {f["rule"] for f in errs} == {"bytes-match", "wire-dtype"}
    assert all("injected-f32-wire" in f["cell"] for f in errs)
    # the honest cell contributed no errors
    assert report["summary"]["error"] == len(errs)


def test_graph_extraction_per_transport():
    """Satellite check: one cell per transport, per-op expectations
    (kinds + byte sizes, replica groups, channel ids, ring pairs)
    against the lifted graph of the real compiled HLO."""
    _run("""
import json
from repro.analysis.cells import Cell, compile_cell

EXPECT = {
    # cell id -> sorted multiset of (kind, operand_bytes, result_bytes)
    "cocoa=persistent": [
        ("all-reduce", 4, 4), ("all-reduce", 384, 384)],
    "minibatch_sgd=spark_faithful": [
        ("all-gather", 1024, 4096), ("all-reduce", 4, 4)],
    "minibatch_scd=reduce_scatter": [
        ("all-gather", 96, 384), ("all-reduce", 4, 4),
        ("reduce-scatter", 384, 96)],
    "cocoa=compressed:int8": [
        ("all-gather", 4, 16), ("all-gather", 96, 384),
        ("all-reduce", 4, 4)],
    "cocoa=compressed:int4/ring": [
        ("all-reduce", 4, 4),
        ("collective-permute", 4, 4), ("collective-permute", 4, 4),
        ("collective-permute", 4, 4),
        ("collective-permute", 48, 48), ("collective-permute", 48, 48),
        ("collective-permute", 48, 48)],
}
RING = ((0, 1), (1, 2), (2, 3), (3, 0))

mesh = None
for cell_id, expect in EXPECT.items():
    algo, _, spec = cell_id.partition("=")
    ctx = compile_cell(Cell(algo, spec), mesh=mesh)
    mesh = ctx.mesh
    assert ctx.K == 4, ctx.K
    got = sorted((op.kind, op.operand_bytes, op.result_bytes)
                 for op in ctx.graph.collectives)
    assert got == sorted(expect), (cell_id, got)
    chans = [op.channel_id for op in ctx.graph.collectives]
    assert None not in chans and len(set(chans)) == len(chans), \\
        (cell_id, chans)
    for op in ctx.graph.collectives:
        if op.kind == "collective-permute":
            assert op.source_target_pairs == RING, (cell_id, op.name)
        else:
            assert op.replica_groups == ((0, 1, 2, 3),), \\
                (cell_id, op.name, op.replica_groups)
    print("ok", cell_id)
print("EXTRACTION-OK")
""", ndev=4)
