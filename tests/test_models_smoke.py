"""Per-arch smoke tests: REDUCED variant of each assigned architecture
runs one forward + one train step on CPU, asserting shapes + finiteness.
Also: decode == teacher-forcing consistency per family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import padded_vocab
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.train import make_train_step


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, min(100, cfg.vocab_size), (B, S)),
                       jnp.int32)
    batch = {"tokens": toks,
             "labels": jnp.concatenate(
                 [toks[:, 1:], jnp.full((B, 1), -100, jnp.int32)], 1)}
    if cfg.family == "audio":
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.encdec.source_len, cfg.d_model)) * .02,
            jnp.bfloat16)
    if cfg.family == "vlm":
        P = 8
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)) * .02, jnp.bfloat16)
        batch["patch_positions"] = jnp.zeros((B, P, 3), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = model.forward_train(params, batch)
    assert logits.shape == (2, 32, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_one_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    params2, opt2, m = step(params, opt, _batch(cfg, seed=2))
    assert bool(jnp.isfinite(m["loss"])) and float(m["loss"]) > 0
    assert bool(jnp.isfinite(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed
    assert int(opt2["count"]) == 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-2.7b",
                                  "recurrentgemma-9b", "chatglm3-6b",
                                  "nemotron-4-15b", "command-r-35b",
                                  "qwen2-vl-72b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, 100)
    logits_full, _ = model.forward_train(params, {"tokens": toks})
    states = model.init_states(params, B, S)
    outs = []
    for t in range(S):
        sb = {"tokens": toks[:, t:t + 1],
              "positions": jnp.full((B, 1), t, jnp.int32)}
        lg, states = model.decode_step(params, sb, states)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, 1)
    d = float(jnp.max(jnp.abs(jax.nn.log_softmax(logits_full)
                              - jax.nn.log_softmax(inc))))
    assert d < 0.15, d  # bf16 accumulation-order tolerance


@pytest.mark.parametrize("arch", ["deepseek-v3-671b",
                                  "llama4-maverick-400b-a17b"])
def test_moe_decode_matches_with_no_drop_capacity(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, 100)
    logits_full, _ = model.forward_train(params, {"tokens": toks})
    states = model.init_states(params, B, S)
    outs = []
    for t in range(S):
        sb = {"tokens": toks[:, t:t + 1],
              "positions": jnp.full((B, 1), t, jnp.int32)}
        lg, states = model.decode_step(params, sb, states)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, 1)
    d = float(jnp.max(jnp.abs(jax.nn.log_softmax(logits_full)
                              - jax.nn.log_softmax(inc))))
    assert d < 0.15, d


def test_whisper_decode_consistency():
    cfg = get_config("whisper-tiny").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 10
    batch = _batch(cfg, B=B, S=S)
    logits_full, _ = model.forward_train(params, batch)
    states = model.init_states(params, B, S,
                               batch={"frame_embeds": batch["frame_embeds"]})
    outs = []
    for t in range(S):
        sb = {"tokens": batch["tokens"][:, t:t + 1],
              "positions": jnp.full((B, 1), t, jnp.int32)}
        lg, states = model.decode_step(params, sb, states)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, 1)
    d = float(jnp.max(jnp.abs(jax.nn.log_softmax(logits_full)
                              - jax.nn.log_softmax(inc))))
    assert d < 0.15, d


def test_sliding_window_variant_limits_context():
    """With window W, logits for position t must not depend on tokens
    further than W back."""
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 24
    t1 = jax.random.randint(jax.random.key(1), (B, S), 0, 100)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] + 7) % 100)  # mutate far-past tokens
    l1, _ = model.forward_train(params, {"tokens": t1})
    l2, _ = model.forward_train(params, {"tokens": t2})
    # last position attends only to the last 8 positions
    np.testing.assert_allclose(l1[:, -1], l2[:, -1], rtol=2e-2, atol=2e-2)


def test_mrope_distinct_positions_change_logits():
    cfg = get_config("qwen2-vl-72b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    l1, _ = model.forward_train(params, batch)
    batch2 = dict(batch)
    batch2["patch_positions"] = jnp.ones_like(batch["patch_positions"]) * 5
    l2, _ = model.forward_train(params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3


def test_ssd_gradients_finite_longer_seq():
    """Regression: masked-exp in the SSD intra-chunk kernel poisoned
    gradients (inf*0=NaN) once seq spanned multiple chunks."""
    cfg = get_config("mamba2-2.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, B=2, S=96, seed=5)   # 3 SSD chunks of 32
    from repro.train.loss import lm_loss
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(model, p, batch)[0])(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in jax.tree.leaves(grads))
