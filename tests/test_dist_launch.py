"""The real multi-process entry (``repro.launch.dist``): a 2-process
``jax.distributed`` CPU run must be BIT-identical to the single-process
reference with the same worker count (2 faked host devices) — same
per-round primals, same SHA-256 of the final shared and local state.
This is the contract that makes the multi-process fabric a deployment
detail rather than a numerics change, for both the fused ``xla``
backend and the explicit ``ring`` one.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _base_env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)          # children control their devices
    return env


def _dist_cmd(spec: str, rounds: int, out: str) -> list:
    return [sys.executable, "-m", "repro.launch.dist",
            "--algorithm", "cocoa", "--exchange", spec,
            "--rounds", str(rounds), "--H", "8",
            "--m", "64", "--n", "128", "--out", out]


def _run_pair_and_reference(spec: str, tmp_path, rounds: int = 3):
    """Launch the 2-process run (1 CPU device per process) and the
    single-process reference (2 faked devices), return the three
    result dicts."""
    port = _free_port()
    outs = [str(tmp_path / f"{i}.json") for i in ("p0", "p1", "ref")]

    procs = []
    for pid in (0, 1):
        procs.append(subprocess.Popen(
            _dist_cmd(spec, rounds, outs[pid])
            + ["--coordinator", f"127.0.0.1:{port}",
               "--num-processes", "2", "--process-id", str(pid)],
            env=_base_env(), stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True))
    ref_env = _base_env()
    ref_env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    procs.append(subprocess.Popen(
        _dist_cmd(spec, rounds, outs[2]), env=ref_env,
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))

    for p in procs:
        out, err = p.communicate(timeout=560)
        assert p.returncode == 0, out + "\n" + err
    results = []
    for path in outs:
        with open(path) as f:
            results.append(json.load(f))
    return results


def test_two_process_matches_single(tmp_path):
    for spec in ("persistent", "compressed:int8/ring"):
        p0, p1, ref = _run_pair_and_reference(spec, tmp_path)
        assert p0["workers"] == p1["workers"] == ref["workers"] == 2
        assert p0["num_processes"] == 2 and ref["num_processes"] == 1
        assert p0["exchange"] == ref["exchange"]
        # every process of the distributed run reports the same result,
        # and it is bit-for-bit the single-process trajectory
        for key in ("primals", "final_shared_sha256", "final_local_sha256"):
            assert p0[key] == p1[key], (spec, key)
            assert p0[key] == ref[key], (spec, key)
