"""The update-codec layer's non-numeric contracts: scheme parsing
(transport x codec composition), wire-byte formulas, config validation,
the trade-off-layer threading, and the suggest_H cap regression.

The numeric round-trip/bit-identity properties live in
tests/test_distributed.py (the hypothesis/fallback battery over all
codecs); this file covers the plumbing those properties ride on.
"""
import numpy as np
import pytest

from repro.comm import CODECS, UpdateCodec, get_codec
from repro.core.distributed import COMM_TRANSPORTS, CommScheme
from repro.optim.local_updates import (LocalUpdatesConfig, delta_wire_bytes,
                                       suggest_H)


# ---------------------------------------------------------------- parsing
def test_scheme_parses_transport_and_codec():
    assert CommScheme.parse("persistent").transport == "persistent"
    assert CommScheme.parse("persistent").codec.name == "f32"
    # bare "compressed" aliases the pre-codec int8 path
    assert CommScheme.parse("compressed").codec.name == "int8"
    assert CommScheme.parse("compressed:int8").codec.name == "int8"
    assert CommScheme.parse("compressed:int4").codec.name == "int4"
    assert CommScheme.parse("compressed:f32").codec.name == "f32"
    for transport in COMM_TRANSPORTS:
        assert CommScheme.parse(transport).transport == transport


def test_scheme_rejects_bad_codec_compositions():
    with pytest.raises(ValueError, match="unknown comm scheme"):
        CommScheme.parse("persistant")
    with pytest.raises(ValueError, match="unknown update codec"):
        CommScheme.parse("compressed:int3")
    # exact transports move f32 by construction — no codec suffix
    for scheme in ("persistent:int8", "reduce_scatter:int4",
                   "spark_faithful:f32"):
        with pytest.raises(ValueError, match="codec suffix"):
            CommScheme.parse(scheme)


def test_get_codec_registry():
    for name in ("f32", "int8", "int4", "int2"):
        assert isinstance(get_codec(name), UpdateCodec)
        assert get_codec(name) is CODECS[name]
    with pytest.raises(ValueError, match="unknown update codec"):
        get_codec("bf16")


def test_get_codec_grammar_compositions():
    """The ef:/topk grammar: canonical names, idempotent cache, and the
    typed rejections (lossless base, nested ef, bad keep ratio) — in
    BOTH get_codec and the scheme parser."""
    assert get_codec("ef:int4").name == "ef:int4"
    assert get_codec("ef:int4").base is get_codec("int4")
    assert get_codec("ef:int4") is get_codec("ef:int4")  # cached
    assert get_codec("topk").name == f"topk(r={0.01:g})"
    assert get_codec("topk(r=0.125)").name == "topk(r=0.125)"
    assert get_codec("ef:topk(r=0.125)").stateful
    for parse in (get_codec, lambda n: CommScheme.parse(f"compressed:{n}")):
        with pytest.raises(ValueError, match="no quantization error"):
            parse("ef:f32")
        with pytest.raises(ValueError, match="does not nest"):
            parse("ef:ef:int8")
        with pytest.raises(ValueError, match="0 < r <= 1"):
            parse("topk(r=0)")
        with pytest.raises(ValueError, match="0 < r <= 1"):
            parse("topk(r=1.5)")
        with pytest.raises(ValueError, match=r"topk\(r=<float>\)"):
            parse("topk(r=lots)")
        with pytest.raises(ValueError, match="unknown update codec"):
            parse("int3")


# ------------------------------------------------------------ wire bytes
@pytest.mark.parametrize("L", [1, 2, 7, 96, 97, 256])
def test_codec_wire_bytes_formulas(L):
    assert get_codec("f32").wire_bytes(L) == 4 * L
    assert get_codec("int8").wire_bytes(L) == L + 4
    # packed int4: ceil(L/2) payload + the 4-byte f32 scale
    assert get_codec("int4").wire_bytes(L) == -(-L // 2) + 4
    # packed int2: ceil(L/4) payload + the scale
    assert get_codec("int2").wire_bytes(L) == -(-L // 4) + 4
    # topk: (f32 value + i32 index) per kept entry + the f32 threshold
    k = min(L, max(1, -(-L // 8)))
    assert get_codec("topk(r=0.125)").wire_bytes(L) == 8 * k + 4
    # the ef: wrapper changes WHAT is encoded, not the wire format
    for base in ("int8", "int4", "int2", "topk(r=0.125)"):
        assert (get_codec(f"ef:{base}").wire_bytes(L)
                == get_codec(base).wire_bytes(L))


@pytest.mark.parametrize("L,K", [(96, 4), (97, 4), (256, 8)])
def test_compressed_scheme_bytes_scale_with_codec(L, K):
    """2 * K * wire_bytes for every codec under the compressed
    transport — the number the drivers benchmark pins to the HLO."""
    for codec in ("f32", "int8", "int4", "int2", "topk(r=0.125)",
                  "ef:int4", "ef:int2"):
        scheme = CommScheme.parse(f"compressed:{codec}")
        assert (scheme.bytes_per_round(L, K)
                == 2 * K * get_codec(codec).wire_bytes(L))
    # and the compression ladder is strictly ordered
    assert (CommScheme.parse("compressed:int4").bytes_per_round(L, K)
            < CommScheme.parse("compressed:int8").bytes_per_round(L, K)
            < CommScheme.parse("compressed:f32").bytes_per_round(L, K))


def test_timemodel_charges_codec_bytes():
    """The trade-off layer sees the codec through bytes_per_round: a
    cheaper codec means a cheaper wire term at identical overhead."""
    from repro.bench.timing import synthetic_link
    from repro.core import PROFILES
    from repro.core.tradeoff import TimeModel

    link = synthetic_link(1e9, 0.0)
    times = {}
    for codec in ("f32", "int8", "int4"):
        nbytes = CommScheme.parse(
            f"compressed:{codec}").bytes_per_round(4096, 8)
        model = TimeModel(PROFILES["E_mpi"], nbytes, link)
        times[codec] = model.comm_time_s()
    assert times["int4"] < times["int8"] < times["f32"]
    assert times["int4"] == pytest.approx(
        2 * 8 * (2048 + 4) / 1e9)


def test_sweep_cfg_accepts_codec_schemes():
    """sweep_H's config path threads codec-suffixed schemes end to end
    (cfg validation, trainer scheme, byte accounting)."""
    from repro.core import CoCoAConfig, CoCoATrainer
    from repro.data import make_glm_data

    A, b, _ = make_glm_data(m=48, n=96, density=0.3, seed=1)
    tr = CoCoATrainer(CoCoAConfig(K=4, H=8, exchange="compressed:int4"),
                      A, b)
    assert tr.comm_bytes_per_round() == 2 * 4 * (24 + 4)
    hist = tr.run(3, record_every=3)
    assert len(hist.primal) == 1


# ------------------------------------------------------- local updates
def test_local_updates_config_validates_codec():
    LocalUpdatesConfig(codec="int8")
    LocalUpdatesConfig(codec="int2")
    LocalUpdatesConfig(codec="ef:int4")  # passes the delta-only check
    with pytest.raises(ValueError, match="unknown update codec"):
        LocalUpdatesConfig(codec="int3")
    # grammar errors surface with their typed messages, not a generic one
    with pytest.raises(ValueError, match="no quantization error"):
        LocalUpdatesConfig(codec="ef:f32")
    with pytest.raises(ValueError, match="does not nest"):
        LocalUpdatesConfig(codec="ef:ef:int8")
    for lossy in ("int8", "ef:int4", "topk(r=0.125)"):
        with pytest.raises(ValueError, match="average='delta'"):
            LocalUpdatesConfig(codec=lossy, average="params")
    LocalUpdatesConfig(codec="f32", average="params")  # lossless is fine


def test_delta_wire_bytes_sums_leaves():
    params = {"w": np.zeros((3, 5), np.float32),
              "b": np.zeros((7,), np.float32)}
    K = 4
    # f32 runs lax.pmean — one all-reduce of the raw 4-byte elements
    # per leaf (no wire tuple, no scale), master-centric 2K pricing
    assert (delta_wire_bytes(params, LocalUpdatesConfig(codec="f32"), K)
            == 2 * K * 4 * 22)
    assert (delta_wire_bytes(params, LocalUpdatesConfig(codec="int8"), K)
            == 2 * K * ((15 + 4) + (7 + 4)))
    assert (delta_wire_bytes(params, LocalUpdatesConfig(codec="int4"), K)
            == 2 * K * ((8 + 4) + (4 + 4)))
    assert (delta_wire_bytes(params, LocalUpdatesConfig(codec="int2"), K)
            == 2 * K * ((4 + 4) + (2 + 4)))
    # topk(r=0.125): k = ceil(0.125 * 15) = 2 resp. ceil(0.125 * 7) = 1
    assert (delta_wire_bytes(
                params, LocalUpdatesConfig(codec="topk(r=0.125)"), K)
            == 2 * K * ((8 * 2 + 4) + (8 * 1 + 4)))
    # ef: prices as its base codec — same wire arrays on the gather
    for base in ("int8", "int4", "int2", "topk(r=0.125)"):
        assert (delta_wire_bytes(
                    params, LocalUpdatesConfig(codec=f"ef:{base}"), K)
                == delta_wire_bytes(
                    params, LocalUpdatesConfig(codec=base), K))


# ----------------------------------------------------------- suggest_H
def test_suggest_H_respects_non_power_of_two_cap():
    """Regression: the doubling loop used to overshoot a non-power-of-
    two max_H (comm-dominated regimes returned 64 for max_H=48)."""
    h = suggest_H(t_compute_per_step=1e-4, t_collective_per_sync=10.0,
                  max_H=48)
    assert h == 48
    for max_H in (1, 3, 5, 48, 100):
        assert suggest_H(1e-4, 10.0, max_H=max_H) <= max_H
    # the clamp must not disturb the interior optimum
    assert suggest_H(1.0, 0.01, max_H=48) == 1
    assert suggest_H(0.1, 0.8, max_H=48) == suggest_H(0.1, 0.8, max_H=64)
