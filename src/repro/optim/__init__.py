from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig  # noqa: F401
from repro.optim.local_updates import (LocalUpdatesConfig,  # noqa: F401
                                       delta_wire_bytes,
                                       init_delta_codec_state,
                                       local_updates_round, suggest_H)
from repro.optim.schedules import cosine_schedule  # noqa: F401
