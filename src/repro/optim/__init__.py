from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig  # noqa: F401
from repro.optim.local_updates import LocalUpdatesConfig, local_updates_round  # noqa: F401
from repro.optim.schedules import cosine_schedule  # noqa: F401
