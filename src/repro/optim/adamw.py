"""AdamW with global-norm clipping, built from scratch (no optax dep).

Optimizer state dtype is configurable: f32 (default, exact) or bf16
(the memory-saving mode used for the >100B MoE configs in the dry-run —
see EXPERIMENTS.md §Dry-run memory notes).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # float32 | bfloat16


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_n = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_n = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (mu_n / b1c) / (jnp.sqrt(nu_n / b2c) + cfg.eps)
        # decoupled weight decay (skipped for 1-D params: norms/biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - cfg.lr * lr_scale * (
            step + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), mu_n.astype(dt), nu_n.astype(dt)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, {
        "grad_norm": gnorm}
