"""The paper's H-knob at transformer scale: communication-avoiding
data-parallel training via local update rounds.

The paper's central finding is that the number of local solver steps per
communication round (H) must be tuned to the framework's per-round
overhead. For the transformer substrate the analogous knob is *local
SGD / FedAvg-style* data parallelism: every data shard runs H optimizer
steps on its own microbatches, then parameter deltas are averaged across
the data axis — one collective per H steps instead of per step.

H = 1 with SGD is exactly synchronous data-parallel (property-tested);
larger H trades gradient staleness for an H-fold reduction in collective
traffic, profitable exactly when the roofline collective term dominates
(see ``suggest_H``).

Orthogonal to H, ``LocalUpdatesConfig.codec`` picks the wire codec for
the delta exchange (``repro.comm``): ``f32`` keeps the exact ``pmean``;
any lossy codec (``int8``/``int4``/``int2``/``topk(r=..)`` and their
``ef:`` error-feedback wrappers) quantizes or sparsifies each leaf's
delta per shard (the same codec objects — and on TPU the same fused
Pallas quantize+pack kernels — as the linear solvers' ``compressed``
comm scheme), all-gathers the encoded payloads, and decodes + means
locally. Stateful ``ef:`` codecs additionally carry a per-shard,
per-leaf residual (:func:`init_delta_codec_state`) so the grid error
feeds back instead of accumulating a bias floor. Deltas after H small
steps are the natural thing to quantize — their dynamic range is tiny
next to the parameters', so the absmax grid is fine where quantizing
raw params would not be; ``average="params"`` therefore rejects a
lossy codec.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm import get_codec
from repro.comm.codec import FP_ITEMSIZE


@dataclass(frozen=True)
class LocalUpdatesConfig:
    H: int = 1                 # local steps per communication round
    average: str = "delta"     # delta | params  (identical result; delta
    #                            keeps the psum operand small vs donated p0)
    sync_opt_state: bool = True
    codec: str = "f32"         # wire codec for the delta exchange

    def __post_init__(self):
        # parse through the full codec grammar — typos and malformed
        # compositions (ef:f32, ef:ef:int8, topk(r=0)) raise their
        # typed errors here, not at trace time
        codec = get_codec(self.codec)
        if not codec.lossless and self.average != "delta":
            raise ValueError(
                f"codec={self.codec!r} requires average='delta': the "
                f"absmax grid is sized to the small per-round deltas — "
                f"quantizing full parameters would be lossy at a "
                f"completely different magnitude")


def delta_wire_bytes(params, cfg: LocalUpdatesConfig, K: int) -> int:
    """Modelled bytes on the wire for ONE delta exchange across K data
    shards, per codec path (opt-state sync, always f32, not included):

    * lossless (``f32``): the round runs ``lax.pmean`` — ONE f32
      all-reduce per leaf, priced master-centrically at
      ``2 * K * 4 * leaf_len`` (operand up, aggregate back), the same
      convention :func:`repro.analysis.traffic.derived_round_traffic`
      applies to the compiled HLO;
    * lossy codecs: per-shard encode + all-gather of the wire arrays,
      ``2 * K * codec.wire_bytes(leaf_len)`` — identical accounting to
      the linear drivers' ``compressed`` scheme (the ``ef:`` wrapper
      changes what is encoded, not the wire format, so it prices as
      its base codec).

    A regression test lowers the round per codec and pins this model
    against the HLO-derived bytes."""
    codec = get_codec(cfg.codec)
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        if codec.lossless:
            total += 2 * K * FP_ITEMSIZE * leaf.size
        else:
            total += 2 * K * codec.wire_bytes(leaf.size)
    return total


def init_delta_codec_state(params, cfg: LocalUpdatesConfig):
    """Per-leaf codec state for the delta exchange: a pytree of flat
    f32 residuals (one per params leaf) when ``cfg.codec`` is stateful
    (the ``ef:`` wrapper), else None. Thread the result through
    ``local_updates_round(..., codec_state=...)`` round over round —
    each data shard carries its OWN copy (it is per-worker state, so
    place it sharded, not replicated)."""
    codec = get_codec(cfg.codec)
    if not getattr(codec, "stateful", False):
        return None
    return jax.tree.map(lambda leaf: codec.init_state(leaf.size), params)


def _codec_mean(delta: jax.Array, codec, axis_name: str, state=None):
    """The compressed replacement for ``lax.pmean`` on one f32 leaf:
    encode this shard's delta, all-gather the wire arrays, and average
    through the codec's fused decode+reduce (Pallas kernel on TPU,
    sequential oracle elsewhere — no (K, L) f32 stack) — the exact
    collective shape (and byte cost) of the linear drivers'
    ``compressed`` exchange. With ``state`` (a stateful codec's
    per-leaf residual) the encode runs through ``encode_with_state``
    and the new residual is returned alongside the mean."""
    flat = delta.reshape(-1)
    if state is None:
        parts = codec.encode(flat)
    else:
        parts, state = codec.encode_with_state(flat, state)
    gathered = tuple(lax.all_gather(p, axis_name) for p in parts)
    mean = codec.decode_stacked_mean(
        gathered, flat.shape[0]).reshape(delta.shape)
    return mean if state is None else (mean, state)


def local_updates_round(step_fn, params, opt_state, batches,
                        cfg: LocalUpdatesConfig, axis_name: str | None,
                        codec_state=None):
    """Run cfg.H local steps then average across ``axis_name``.

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    must NOT itself synchronize gradients (grad_sync=False in the step
    factory). ``batches`` is a pytree with leading axis H (this shard's
    local microbatches).

    ``codec_state`` (from :func:`init_delta_codec_state`) carries a
    stateful codec's per-shard residuals; when passed, the return grows
    a fourth element — the new state to thread into the next round.
    """
    p0 = params

    def one(carry, mb):
        p, o = carry
        p, o, metrics = step_fn(p, o, mb)
        return (p, o), metrics

    (pH, oH), metrics = lax.scan(one, (params, opt_state), batches)

    if axis_name is not None:
        # reductions in f32: numerically safer, and XLA:CPU's bf16
        # all-reduce promotion pass crashes on sub-byte promotions.
        if cfg.average == "delta":
            delta = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32)
                              - b.astype(jnp.float32)), pH, p0)
            codec = get_codec(cfg.codec)
            if codec.lossless:
                delta = lax.pmean(delta, axis_name)
            elif codec_state is None:
                delta = jax.tree.map(
                    lambda d: _codec_mean(d, codec, axis_name), delta)
            else:
                dl, treedef = jax.tree_util.tree_flatten(delta)
                sl = jax.tree_util.tree_leaves(codec_state)
                out = [_codec_mean(d, codec, axis_name, s)
                       for d, s in zip(dl, sl)]
                delta = treedef.unflatten([m for m, _ in out])
                codec_state = treedef.unflatten([s for _, s in out])
            pH = jax.tree.map(lambda p, d: (p.astype(jnp.float32)
                                            + d).astype(p.dtype), p0, delta)
        else:
            pH = jax.tree.map(
                lambda x: lax.pmean(x.astype(jnp.float32),
                                    axis_name).astype(x.dtype), pH)
        if cfg.sync_opt_state:
            oH = jax.tree.map(
                lambda x: lax.pmean(x.astype(jnp.float32),
                                    axis_name).astype(x.dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, oH)
    if codec_state is None:
        return pH, oH, metrics
    return pH, oH, metrics, codec_state


def suggest_H(t_compute_per_step: float, t_collective_per_sync: float,
              max_H: int = 64, staleness_budget: float = 0.25) -> int:
    """Roofline-driven H selection (the paper's Fig-6 logic, automated).

    Picks the smallest H whose per-step amortized communication cost is
    <= staleness_budget * compute, capped at max_H — i.e. spend at least
    1/(1+budget) of the time computing, mirroring the paper's optimal
    compute fractions (60-97%) rising with per-round overhead.
    """
    H = 1
    while (H < max_H
           and t_collective_per_sync / H > staleness_budget
           * max(t_compute_per_step, 1e-12)):
        H *= 2
    # the doubling loop can overshoot a non-power-of-two cap (max_H=48
    # used to return 64): max_H is a hard ceiling, so clamp
    return min(H, max_H)
