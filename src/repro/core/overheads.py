"""Framework-overhead model — the paper's central measurement, §5.2/Fig 3.

The paper isolates ``T_overhead = T_tot - T_worker - T_master`` by running
byte-identical native (C++) local solvers under Spark/Scala, pySpark and
MPI. We reproduce the *methodology* on one host: the local-solver compute
time is **measured live** (our Pallas/ref solver plays the role of the
C++ module), and each implementation (A)-(E) contributes

  t_round(H) = compute_mult * t_solver(H)  +  overhead_units * T_ref

where ``T_ref`` is the measured solver time at the calibration point
H = n_local (the setting of Fig 3), and the dimensionless constants are
calibrated to the paper's stated ratios:

  * C++ offload speeds up the Scala solver ~10x and the Python solver
    >100x (Fig 3 discussion)                 -> compute_mult 10 / 150.
  * pySpark overheads are 15x Spark/Scala's  -> C = 15 * A.
  * flat-format Scala reduces overhead 3x    -> B = A / 3.
  * persistent-local-memory + meta-RDD cut overheads 3x (Scala) and
    10x (Python)                             -> B* = B/3, D* = D/10.
  * MPI overhead is ~3% of total time        -> E ~= 0.03 units.
  * Python-C API adds slight overhead on top of pySpark -> D = C + 1.

With T_worker(C++) := 1 unit (~= 30s/100 rounds in Fig 3), the paper's
bars give A ~= 2.0 units of overhead and C ~= 30 units.

``OverheadProfile.round_time`` charges framework overhead only; the
scheme's communication wall-clock (bytes / measured bandwidth + latency)
is charged on top by ``repro.core.tradeoff.TimeModel``, which wraps a
profile together with :func:`communicated_bytes_per_round`-style traffic
and a link calibration from ``repro.bench.timing.calibrate_link``.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class OverheadProfile:
    name: str
    description: str
    compute_mult: float       # local-solver slowdown vs native/C++ module
    overhead_units: float     # per-round framework overhead, units of T_ref
    persistent_alpha: bool    # may keep alpha_[k] resident across rounds?

    def round_time(self, t_solver_s: float, t_ref_s: float,
                   t_master_s: float = 0.0) -> float:
        return (self.compute_mult * t_solver_s
                + self.overhead_units * t_ref_s + t_master_s)

    def compute_fraction(self, t_solver_s: float, t_ref_s: float) -> float:
        c = self.compute_mult * t_solver_s
        return c / max(c + self.overhead_units * t_ref_s, 1e-30)


PROFILES: dict[str, OverheadProfile] = {
    "A_spark": OverheadProfile(
        "A_spark", "Spark/Scala reference (Breeze local solver)",
        compute_mult=10.0, overhead_units=2.0, persistent_alpha=False),
    "B_spark_c": OverheadProfile(
        "B_spark_c", "Spark/Scala + JNI C++ solver, flat RDD format",
        compute_mult=1.05, overhead_units=2.0 / 3.0, persistent_alpha=False),
    "C_pyspark": OverheadProfile(
        "C_pyspark", "pySpark reference (NumPy local solver)",
        compute_mult=150.0, overhead_units=30.0, persistent_alpha=False),
    "D_pyspark_c": OverheadProfile(
        "D_pyspark_c", "pySpark + Python-C API C++ solver",
        compute_mult=1.0, overhead_units=31.0, persistent_alpha=False),
    "B_spark_opt": OverheadProfile(
        "B_spark_opt", "(B)* persistent local memory + meta-RDD (Scala)",
        compute_mult=1.05, overhead_units=2.0 / 9.0, persistent_alpha=True),
    "D_pyspark_opt": OverheadProfile(
        "D_pyspark_opt", "(D)* persistent local memory + meta-RDD (Python)",
        compute_mult=1.0, overhead_units=3.1, persistent_alpha=True),
    "E_mpi": OverheadProfile(
        "E_mpi", "MPI/C++ reference",
        compute_mult=1.0, overhead_units=0.031, persistent_alpha=True),
}


def communicated_bytes_per_round(m: int, n: int, K: int,
                                 persistent_alpha: bool,
                                 itemsize: int = 4,
                                 scheme: str | None = None) -> int:
    """Bytes through the master per round (paper Fig 1 + §5.3).

    Always: K workers send the m-vector Delta v up, receive v back.
    Non-persistent schemes additionally ship the full alpha up and down.
    Every dense array in the system is float32, hence ``itemsize=4``.

    ``scheme`` (any of ``repro.core.distributed.COMM_SCHEMES``) switches
    to the :class:`repro.core.distributed.CommScheme` accounting, which
    also covers the int8 ``compressed`` exchange (m bytes + a 4-byte f32
    scale per worker, each way) and the masterless ``reduce_scatter``
    ring (2*(K-1)/K of the K-padded vector per worker each way), and
    overrides ``persistent_alpha`` / ``itemsize``. The alpha round-trip
    then counts K zero-padded
    ``ceil(n/K)`` blocks — the even/block-partition layout (the analytic
    path below keeps the paper's unpadded ``n``). For a concrete trainer
    prefer ``CoCoATrainer.comm_bytes_per_round()``: the balanced
    partitioner may pad blocks beyond ``ceil(n/K)`` under skewed nnz,
    and only the trainer knows the actual padded size the collectives
    move (what the ``drivers`` benchmark asserts against the HLO).
    """
    if scheme is not None:
        # local import keeps this module import-light (no jax) for the
        # pure model-calibration path
        from repro.core.distributed import CommScheme
        n_moved = -(n // -K) * K  # K padded blocks of ceil(n/K)
        return CommScheme.parse(scheme).bytes_per_round(
            m, K, local_state_len=n_moved)
    v_traffic = 2 * K * m * itemsize
    a_traffic = 0 if persistent_alpha else 2 * n * itemsize
    return v_traffic + a_traffic
