"""Generalized linear model objectives for the paper's workload.

The paper trains elastic-net-regularized least squares (ridge for eta=1):

    P(alpha) = 1/2 ||A alpha - b||^2
               + lam * ( eta/2 ||alpha||^2 + (1-eta) ||alpha||_1 )

with the data matrix ``A`` partitioned **column-wise** across workers
(each worker owns a block of features / coordinates of alpha).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class GLMProblem:
    """An elastic-net regression problem instance."""
    lam: float = 1.0         # regularization strength
    eta: float = 1.0         # 1.0 => pure ridge; 0.0 => pure lasso

    def regularizer(self, alpha: jax.Array) -> jax.Array:
        l2 = 0.5 * self.eta * jnp.sum(alpha * alpha)
        l1 = (1.0 - self.eta) * jnp.sum(jnp.abs(alpha))
        return self.lam * (l2 + l1)

    def loss(self, residual: jax.Array) -> jax.Array:
        """f(v) = 1/2 ||v - b||^2 expressed on the residual w = v - b."""
        return 0.5 * jnp.sum(residual * residual)


def primal_objective(problem: GLMProblem, A: jax.Array, b: jax.Array,
                     alpha: jax.Array) -> jax.Array:
    r = A @ alpha - b
    return problem.loss(r) + problem.regularizer(alpha)


def primal_from_state(problem: GLMProblem, w: jax.Array,
                      reg_sum: jax.Array) -> jax.Array:
    """Objective from the shared residual ``w = A alpha - b`` plus the
    (possibly psum'd) regularizer value — what the master can evaluate
    without ever gathering alpha (the persistent-local-memory scheme)."""
    return problem.loss(w) + reg_sum


def ridge_exact(A: np.ndarray, b: np.ndarray, lam: float) -> np.ndarray:
    """Closed-form ridge solution (eta=1):  (A^T A + lam I)^-1 A^T b."""
    n = A.shape[1]
    return np.linalg.solve(A.T @ A + lam * np.eye(n), A.T @ b)


def optimal_objective(problem: GLMProblem, A: np.ndarray, b: np.ndarray,
                      n_iters: int = 200_000) -> float:
    """High-precision P* — closed form for ridge, else proximal gradient."""
    if problem.eta == 1.0:
        alpha = ridge_exact(A, b, problem.lam)
        return float(primal_objective(problem, jnp.asarray(A), jnp.asarray(b),
                                      jnp.asarray(alpha)))
    # FISTA for the elastic-net case.
    A_j, b_j = jnp.asarray(A), jnp.asarray(b)
    L = float(np.linalg.norm(A, 2) ** 2 + problem.lam * problem.eta)
    thresh = problem.lam * (1.0 - problem.eta) / L

    @jax.jit
    def step(carry, _):
        alpha, y, t = carry
        grad = A_j.T @ (A_j @ y - b_j) + problem.lam * problem.eta * y
        z = y - grad / L
        alpha_new = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thresh, 0.0)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = alpha_new + (t - 1.0) / t_new * (alpha_new - alpha)
        return (alpha_new, y_new, t_new), ()

    n = A.shape[1]
    init = (jnp.zeros(n), jnp.zeros(n), jnp.asarray(1.0))
    (alpha, _, _), _ = jax.lax.scan(step, init, None, length=min(n_iters, 20000))
    return float(primal_objective(problem, A_j, b_j, alpha))


def suboptimality(p_now: float, p_star: float, p_zero: float) -> float:
    """Normalized suboptimality in [0, 1]:  (P - P*) / (P(0) - P*)."""
    denom = max(p_zero - p_star, 1e-30)
    return max(p_now - p_star, 0.0) / denom
