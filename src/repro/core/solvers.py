"""Local sub-problem solvers (pure-jnp reference implementations).

The CoCoA local subproblem on worker k (elastic net, Appendix A):

    min_{dalpha}  w^T A dalpha + sigma/2 ||A dalpha||^2
                  + sum_{i in P_k} lam*(eta/2 (alpha+dalpha)_i^2
                                        + (1-eta)|(alpha+dalpha)_i|)

solved by H steps of stochastic coordinate descent with *immediate local
updates* (this is what distinguishes CoCoA from mini-batch SCD). The
closed-form single-coordinate update, with local residual state
``rho = w + sigma * A dalpha``:

    z_tilde = (sigma*||c_j||^2 * a_j - rho^T c_j) / (sigma*||c_j||^2 + lam*eta)
    z       = soft_threshold(z_tilde, lam*(1-eta)/(sigma*||c_j||^2 + lam*eta))
    rho    += sigma * c_j * (z - a_j)

The Pallas TPU kernel in ``repro.kernels.scd`` implements the identical
contract (this module is its ``ref`` oracle's home).

Coordinate indices are pre-sampled by the caller so that the reference
and the kernel are bit-comparable given the same index stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def soft_threshold(z: jax.Array, tau) -> jax.Array:
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)


@functools.partial(jax.jit, static_argnames=("unroll",))
def scd_steps(A_k: jax.Array, col_sq: jax.Array, alpha_k: jax.Array,
              w: jax.Array, idx: jax.Array, *, sigma: float, lam: float,
              eta: float, unroll: int = 1):
    """Run len(idx) sequential SCD steps on one worker's column block.

    Args:
      A_k:    (m, n_local) dense local column block (zero-padded cols ok).
      col_sq: (n_local,) squared column norms of A_k.
      alpha_k:(n_local,) local coordinates of alpha.
      w:      (m,) shared residual vector  w = A alpha - b  at round start.
      idx:    (H,) int32 coordinate indices to visit (sampled by caller).

    Returns:
      (delta_v, alpha_new): the m-vector update  A_k @ dalpha  to be
      all-reduced, and the updated local alpha block.
    """
    sigma = jnp.asarray(sigma, w.dtype)
    lam_eta = jnp.asarray(lam * eta, w.dtype)
    lam_l1 = jnp.asarray(lam * (1.0 - eta), w.dtype)

    def body(i, carry):
        alpha, rho = carry
        j = idx[i]
        c = lax.dynamic_index_in_dim(A_k, j, axis=1, keepdims=False)
        csq = col_sq[j]
        a = alpha[j]
        denom = sigma * csq + lam_eta
        # Zero (padded) column -> denom reduces to lam_eta; numerator keeps
        # z == shrinkage of a; guard to make it an exact no-op instead.
        z_tilde = (sigma * csq * a - jnp.dot(rho, c)) / denom
        z = soft_threshold(z_tilde, lam_l1 / denom)
        z = jnp.where(csq > 0, z, a)
        alpha = alpha.at[j].set(z)
        rho = rho + (sigma * (z - a)) * c
        return alpha, rho

    alpha_new, rho = lax.fori_loop(0, idx.shape[0], body, (alpha_k, w),
                                   unroll=unroll)
    delta_v = (rho - w) / sigma
    return delta_v, alpha_new


@functools.partial(jax.jit, static_argnames=())
def scd_steps_fixed_point(A_k, col_sq, alpha_k, w, idx, *, sigma, lam, eta):
    """Mini-batch SCD (SDCA-style) — same coordinate rule but WITHOUT
    immediate local updates: every step sees the round-start residual.
    This is the paper's mini-batch baseline; aggregation across the batch
    is damped by 1/sigma at the caller."""
    sigma = jnp.asarray(sigma, w.dtype)
    lam_eta = jnp.asarray(lam * eta, w.dtype)
    lam_l1 = jnp.asarray(lam * (1.0 - eta), w.dtype)

    def body(i, carry):
        alpha, dv = carry
        j = idx[i]
        c = lax.dynamic_index_in_dim(A_k, j, axis=1, keepdims=False)
        csq = col_sq[j]
        a = alpha[j]
        denom = sigma * csq + lam_eta
        z_tilde = (sigma * csq * a - jnp.dot(w, c)) / denom   # fixed residual w
        z = soft_threshold(z_tilde, lam_l1 / denom)
        z = jnp.where(csq > 0, z, a)
        alpha = alpha.at[j].set(z)
        dv = dv + (z - a) * c
        return alpha, dv

    alpha_new, dv = lax.fori_loop(0, idx.shape[0], body,
                                  (alpha_k, jnp.zeros_like(w)))
    return dv, alpha_new
