"""The communication-computation trade-off machinery (paper §5.5, Figs 6-7).

``H`` — local SCD steps per round — is *the* tuning knob: more local work
per round means fewer (expensive) communication rounds but diminishing
convergence benefit per round. The optimum depends on the framework's
per-round overhead, which is why the paper finds optimal H differing by
>25x between implementations of the same algorithm on the same hardware.

This module provides the sweep + autotuner used by the benchmarks and by
``optim/local_updates.py``'s roofline-driven variant for transformer
training. Sweeps ride the unified distributed-driver layer
(``repro.core.distributed``): ``base_cfg.comm_scheme`` threads through
every grid point. Per-round traffic under a scheme is available via
``CoCoATrainer.comm_bytes_per_round()`` / the scheme-aware
``overheads.communicated_bytes_per_round``; charging it as wall-clock
in the autotuner's time model is still future work (see ROADMAP).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.bench.timing import measure_solver_time  # noqa: F401  (re-export)
from repro.core.cocoa import CoCoAConfig, CoCoATrainer
from repro.core.overheads import OverheadProfile


@dataclass
class HSweepPoint:
    H: int
    rounds_to_eps: int | None
    t_solver_s: float          # measured local-solver wall time per round


@dataclass
class HSweep:
    eps: float
    n_local: int
    t_ref_s: float = float("nan")  # measured t_solver at H = n_local
    points: list = field(default_factory=list)


# measure_solver_time lives in repro.bench.timing (the harness's shared
# warmup/repeat/min discipline) and is re-exported above for back-compat.


def sweep_H(A, b, base_cfg: CoCoAConfig, H_grid, eps: float = 1e-3,
            max_rounds: int = 2000, measure: bool = True) -> HSweep:
    n_local = int(np.ceil(A.shape[1] / base_cfg.K))
    sweep = HSweep(eps=eps, n_local=n_local)
    for H in H_grid:
        cfg = CoCoAConfig(**{**base_cfg.__dict__, "H": int(H)})
        trainer = CoCoATrainer(cfg, A, b)
        hist = trainer.run(max_rounds, record_every=1, target_eps=eps)
        t_s = measure_solver_time(trainer, int(H)) if measure else float("nan")
        sweep.points.append(HSweepPoint(int(H), hist.rounds_to(eps), t_s))
    if measure:
        sweep.t_ref_s = measure_solver_time(
            CoCoATrainer(base_cfg, A, b), n_local)
    return sweep


def time_to_eps(profile: OverheadProfile, point: HSweepPoint,
                t_ref_s: float) -> float:
    if point.rounds_to_eps is None:
        return float("inf")
    return point.rounds_to_eps * profile.round_time(point.t_solver_s, t_ref_s)


def optimal_H(profile: OverheadProfile, sweep: HSweep) -> tuple[int, float]:
    """(H*, time-to-eps at H*) for one framework profile."""
    best = (None, float("inf"))
    for p in sweep.points:
        t = time_to_eps(profile, p, sweep.t_ref_s)
        if t < best[1]:
            best = (p.H, t)
    return best


def compute_fraction_at(profile: OverheadProfile, sweep: HSweep, H: int) -> float:
    for p in sweep.points:
        if p.H == H:
            return profile.compute_fraction(p.t_solver_s, sweep.t_ref_s)
    raise KeyError(H)


def autotune_H(rounds_to_eps_fn, round_time_fn, lo: int, hi: int,
               tol: int = 1) -> int:
    """Golden-section search over integer H minimizing
    rounds_to_eps(H) * round_time(H). Both callables may be models or
    live measurements; used by the beyond-paper auto-adaptive variant."""
    phi = (np.sqrt(5) - 1) / 2

    def cost(H):
        r = rounds_to_eps_fn(int(H))
        return float("inf") if r is None else r * round_time_fn(int(H))

    a, b = float(lo), float(hi)
    c, d = b - phi * (b - a), a + phi * (b - a)
    fc, fd = cost(c), cost(d)
    while b - a > tol:
        if fc <= fd:
            b, d, fd = d, c, fc
            c = b - phi * (b - a)
            fc = cost(c)
        else:
            a, c, fc = c, d, fd
            d = a + phi * (b - a)
            fd = cost(d)
    return int(round((a + b) / 2))
