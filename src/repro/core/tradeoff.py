"""The communication-computation trade-off machinery (paper §5.5, Figs 6-7).

``H`` — local steps per round — is *the* tuning knob: more local work
per round means fewer (expensive) communication rounds but diminishing
convergence benefit per round. The optimum depends on the framework's
per-round overhead AND on the per-round communication wall-clock, which
is why the paper finds optimal H differing by >25x between
implementations of the same algorithm on the same hardware.

This module provides the sweep + autotuner used by the benchmarks and by
``optim/local_updates.py``'s roofline-driven variant for transformer
training. Sweeps ride the unified distributed-driver layer
(``repro.core.distributed``) for **all three algorithms** (CoCoA,
mini-batch SCD, mini-batch SGD-as-local-SGD) under every exchange
regime: ``base_cfg.exchange`` (the unified
:class:`~repro.core.distributed.ExchangeConfig` — comm scheme,
staleness bound, straggler profile, membership schedule) threads
through every grid point, so the sweep matrix spans 3 algorithms x 4
schemes x the staleness/straggler/membership axes.

Per-round traffic under a scheme (``CommScheme.bytes_per_round``,
HLO-verified by the ``drivers`` benchmark) is converted to seconds by
:class:`TimeModel`: ``comm_bytes / measured_bandwidth + latency`` on top
of the framework profile's calibrated overhead, with bandwidth/latency
measured live by ``repro.bench.timing.calibrate_link`` (a ping-pong over
the scheme's actual collective on the current mesh). Every grid point in
``sweep_H`` / ``optimal_H`` / ``autotune_H`` is therefore charged its
scheme's real wall-clock traffic — the paper's Figs 6-7 axis. Under the
``stale`` exchange mode the exchange overlaps the next round's compute,
so the model only charges the overhang ``max(0, t_wire - t_compute)``:
on a slow-but-hideable link that pulls the optimal H back down toward
the fast-link optimum.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.bench.timing import (LinkCalibration, calibrate_link,  # noqa: F401
                                measure_solver_time, synthetic_link)
from repro.comm.collectives import get_backend
from repro.core.baselines import MinibatchSCD, MinibatchSGD, SGDConfig
from repro.core.cocoa import CoCoAConfig, CoCoATrainer
from repro.core.distributed import ExchangeConfig, ExchangeMode
from repro.core.overheads import OverheadProfile
from repro.utils.deprecation import warn_deprecated

SWEEP_ALGORITHMS = ("cocoa", "minibatch_scd", "minibatch_sgd")


class NoConvergedPointError(RuntimeError):
    """No grid point reached the target eps — there is no optimum to
    report. Carries the sweep so callers can show what was tried."""

    def __init__(self, sweep: "HSweep"):
        self.sweep = sweep
        grid = [p.H for p in sweep.points]
        super().__init__(
            f"no H in {grid} reached eps={sweep.eps} "
            f"(algorithm={sweep.algorithm!r}, scheme={sweep.scheme!r}, "
            f"mode={sweep.mode!r})")


@dataclass
class HSweepPoint:
    H: int
    rounds_to_eps: int | None
    t_solver_s: float          # measured local-solver wall time per round


@dataclass
class HSweep:
    eps: float
    n_local: int
    t_ref_s: float = float("nan")  # measured t_solver at H = n_local
    points: list = field(default_factory=list)
    algorithm: str = "cocoa"
    scheme: str = "persistent"     # display: the exchange's scheme name
    mode: str = "sync"             # display: the exchange's mode spec
    comm_bytes_per_round: int = 0  # modelled wire traffic (H-independent)
    exchange: str = "persistent"   # full canonical ExchangeConfig spec
    workers: int = 0               # K the sweep ran with (barrier model)

    def __post_init__(self):
        # legacy construction sites set only the display (scheme, mode)
        # pair; fold it into the canonical spec so for_sweep() — which
        # reads ONLY `exchange` — never silently drops a stale mode
        if self.exchange == "persistent" and (self.scheme != "persistent"
                                              or self.mode != "sync"):
            self.exchange = ExchangeConfig.parse(
                self.scheme if self.mode == "sync"
                else f"{self.scheme}/{self.mode}").spec


# measure_solver_time lives in repro.bench.timing (the harness's shared
# warmup/repeat/min discipline) and is re-exported above for back-compat.


@dataclass(frozen=True)
class TimeModel:
    """Exchange-aware wall-clock model of one round:

        t_round(H) = profile.round_time(barrier_mult * t_solver, t_ref)
                     + comm_bytes_per_round / bandwidth + latency   # sync
                     + max(0, t_wire - k * t_compute)               # stale

    The first term is the paper's calibrated framework overhead
    (§5.2/Fig 3), with the compute term stretched by the exchange's
    straggler profile: a bulk-synchronous round waits for its slowest
    worker (the paper's §4 barrier cost), so compute is charged as
    E[max over the ``workers`` multipliers] x ``t_solver`` instead of
    the scalar. The second charges the scheme's modelled wire traffic
    against a :class:`~repro.bench.timing.LinkCalibration` (measured by
    ``calibrate_link`` or synthetic for what-if studies). Under a stale
    mode nothing waits on the exchange — a ``k``-deep pending queue
    lets it hide behind up to ``k`` rounds of (barrier-stretched)
    compute, so the round only pays the overhang. With ``link=None``
    the model degrades to the bare profile, so every pre-existing call
    site keeps its behavior.

    ``exchange`` is the unified spec (:class:`ExchangeConfig` or spec
    string); the old ``mode=`` string knob is a deprecated alias. A
    straggler-bearing exchange requires ``workers`` (the K the max is
    taken over).
    """
    profile: OverheadProfile
    comm_bytes_per_round: int = 0
    link: LinkCalibration | None = None
    exchange: "ExchangeConfig | str | None" = None
    workers: int = 0
    mode: str | None = None        # DEPRECATED alias -> exchange

    def __post_init__(self):
        if self.mode is not None:
            ex = self.exchange
            if ex is not None and ExchangeMode.parse(self.mode) != \
                    ExchangeConfig.parse(ex).mode:
                raise ValueError(
                    f"TimeModel: mode={self.mode!r} conflicts with "
                    f"exchange={ExchangeConfig.parse(ex).spec!r} — drop "
                    f"the deprecated knob")
            if ex is None:
                warn_deprecated(
                    "TimeModel(mode=...) is deprecated; pass "
                    "exchange='stale:k=2' (or an ExchangeConfig)")
                ex = ExchangeConfig(mode=ExchangeMode.parse(self.mode))
            object.__setattr__(self, "exchange", ex)
            object.__setattr__(self, "mode", None)
        ex = (ExchangeConfig() if self.exchange is None
              else ExchangeConfig.parse(self.exchange))
        object.__setattr__(self, "exchange", ex)
        if ex.straggler.active and self.workers < 1:
            raise ValueError(
                "TimeModel with a straggler profile needs workers=K — "
                "the barrier charges E[max over K workers]")
        if ex.backend != "xla" and self.workers < 1:
            raise ValueError(
                f"TimeModel with the {ex.backend!r} collective backend "
                f"needs workers=K — the hop latency scales with the "
                f"ring size")

    @property
    def name(self) -> str:
        return self.profile.name

    @property
    def barrier_mult(self) -> float:
        """The factor the bulk-synchronous barrier stretches compute
        by: E[max over workers] of the straggler multiplier (1.0 with
        no stragglers)."""
        s = self.exchange.straggler
        return s.expected_barrier_mult(self.workers) if s.active else 1.0

    def comm_time_s(self, t_compute_s: float = 0.0) -> float:
        """Wall seconds the round pays for the wire. ``t_compute_s``
        only matters under a stale mode: the exchange hides behind up
        to ``k`` rounds of that much compute (the pending queue gives
        the collective ``k`` rounds to finish)."""
        if self.link is None or self.comm_bytes_per_round <= 0:
            return 0.0
        ex = self.exchange
        overlap = ex.mode.k * t_compute_s if ex.mode.stale else 0.0
        # the backend owns how many sequential per-hop latencies one
        # exchange pays: 1 for a fused xla collective, up to 2*(K-1)
        # for the explicit ring — the term that shifts autotune_H
        hops = get_backend(ex.backend).latency_hops(
            ex.scheme.transport, self.workers or 1)
        return self.link.seconds_for(self.comm_bytes_per_round, overlap,
                                     latency_hops=max(hops, 1))

    def round_time(self, t_solver_s: float, t_ref_s: float,
                   t_master_s: float = 0.0) -> float:
        t_eff = self.barrier_mult * t_solver_s
        return (self.profile.round_time(t_eff, t_ref_s, t_master_s)
                + self.comm_time_s(self.profile.compute_mult * t_eff))

    def compute_fraction(self, t_solver_s: float, t_ref_s: float) -> float:
        """Fraction of the round doing USEFUL compute: straggler
        barrier slack counts as overhead, not compute."""
        c = self.profile.compute_mult * t_solver_s
        c_barrier = self.barrier_mult * c
        other = ((c_barrier - c) + self.profile.overhead_units * t_ref_s
                 + self.comm_time_s(c_barrier))
        return c / max(c + other, 1e-30)

    def for_sweep(self, sweep: "HSweep") -> "TimeModel":
        """The same model charged with a sweep's modelled traffic and
        run under the sweep's full exchange spec (mode, stragglers,
        membership) and worker count."""
        return dataclasses.replace(
            self, comm_bytes_per_round=sweep.comm_bytes_per_round,
            exchange=sweep.exchange,
            workers=sweep.workers or self.workers)


def make_trainer(algorithm: str, cfg, A, b):
    """One trainer on the unified driver layer; ``cfg`` must match the
    algorithm family (CoCoAConfig for cocoa/minibatch_scd, SGDConfig for
    minibatch_sgd)."""
    if algorithm == "cocoa":
        return CoCoATrainer(cfg, A, b)
    if algorithm == "minibatch_scd":
        return MinibatchSCD(cfg, A, b)
    if algorithm == "minibatch_sgd":
        if not isinstance(cfg, SGDConfig):
            raise TypeError(f"minibatch_sgd needs an SGDConfig, got "
                            f"{type(cfg).__name__}")
        return MinibatchSGD(cfg, A, b)
    raise ValueError(f"unknown algorithm {algorithm!r}; "
                     f"known: {SWEEP_ALGORITHMS}")


def sweep_H(A, b, base_cfg, H_grid, eps: float = 1e-3,
            max_rounds: int = 2000, measure: bool = True,
            algorithm: str = "cocoa") -> HSweep:
    """Measured rounds-to-eps + solver wall time per H for ANY algorithm
    on the driver layer, under ``base_cfg.exchange``. Configs are
    perturbed with ``dataclasses.replace`` (never a ``__dict__`` splat,
    which silently breaks once a dataclass gains derived fields)."""
    n_local = int(np.ceil(A.shape[1] / base_cfg.K))
    ex = base_cfg.exchange
    sweep = HSweep(eps=eps, n_local=n_local, algorithm=algorithm,
                   scheme=ex.scheme.name, mode=ex.mode.spec,
                   exchange=ex.spec, workers=base_cfg.K)
    for H in H_grid:
        cfg = dataclasses.replace(base_cfg, H=int(H))
        trainer = make_trainer(algorithm, cfg, A, b)
        hist = (trainer.run_workers(max_rounds, record_every=1,
                                    target_eps=eps)
                if isinstance(trainer, MinibatchSGD)
                else trainer.run(max_rounds, record_every=1, target_eps=eps))
        t_s = measure_solver_time(trainer, int(H)) if measure else float("nan")
        sweep.points.append(HSweepPoint(int(H), hist.rounds_to(eps), t_s))
        sweep.comm_bytes_per_round = trainer.comm_bytes_per_round()
    if measure:
        sweep.t_ref_s = measure_solver_time(
            make_trainer(algorithm, base_cfg, A, b), n_local)
    return sweep


def time_to_eps(model, point: HSweepPoint, t_ref_s: float) -> float:
    """``model`` is anything with ``round_time(t_solver, t_ref)`` — an
    :class:`OverheadProfile` (overhead only) or a :class:`TimeModel`
    (overhead + scheme traffic charged against the measured link)."""
    if point.rounds_to_eps is None:
        return float("inf")
    return point.rounds_to_eps * model.round_time(point.t_solver_s, t_ref_s)


def optimal_H(model, sweep: HSweep) -> tuple[int, float]:
    """(H*, time-to-eps at H*) for one framework profile / time model.

    Raises :class:`NoConvergedPointError` when no grid point reached the
    sweep's eps (the old ``(None, inf)`` return crashed every caller
    downstream with a ``TypeError`` on ``None`` arithmetic)."""
    best = (None, float("inf"))
    for p in sweep.points:
        t = time_to_eps(model, p, sweep.t_ref_s)
        if t < best[1]:
            best = (p.H, t)
    if best[0] is None:
        raise NoConvergedPointError(sweep)
    return best


def compute_fraction_at(model, sweep: HSweep, H: int) -> float:
    for p in sweep.points:
        if p.H == H:
            return model.compute_fraction(p.t_solver_s, sweep.t_ref_s)
    raise KeyError(f"H={H} is not a sweep grid point "
                   f"(grid: {[p.H for p in sweep.points]})")


def autotune_H(rounds_to_eps_fn, round_time_fn, lo: int, hi: int,
               tol: int = 1) -> int:
    """Golden-section search over integer H minimizing
    rounds_to_eps(H) * round_time(H). Both callables may be models or
    live measurements; used by the beyond-paper auto-adaptive variant.

    The endpoints ``lo``/``hi`` are evaluated explicitly and the argmin
    of EVERY evaluated cost is returned: a boundary optimum (common when
    overhead is tiny, e.g. ``E_mpi``) would otherwise be systematically
    missed, and a midpoint that beats neither probe can never be
    returned."""
    phi = (np.sqrt(5) - 1) / 2
    evaluated: dict[int, float] = {}

    def cost(H):
        H = int(round(H))
        if H not in evaluated:
            r = rounds_to_eps_fn(H)
            evaluated[H] = (float("inf") if r is None
                            else r * round_time_fn(H))
        return evaluated[H]

    cost(lo), cost(hi)
    a, b = float(lo), float(hi)
    c, d = b - phi * (b - a), a + phi * (b - a)
    fc, fd = cost(c), cost(d)
    while b - a > tol:
        if fc <= fd:
            b, d, fd = d, c, fc
            c = b - phi * (b - a)
            fc = cost(c)
        else:
            a, c, fc = c, d, fd
            d = a + phi * (b - a)
            fd = cost(d)
    cost((a + b) / 2)
    return min(evaluated, key=evaluated.get)
