"""Baselines the paper compares against — as first-class algorithms on
the unified distributed-driver layer (``repro.core.distributed``).

* Mini-batch SCD (SDCA-style, no immediate local updates) —
  :class:`MinibatchSCD`: identical partitioning, drivers and comm
  schemes to CoCoA, but every local step sees the round-start residual
  and aggregation is damped by 1/sigma. (Paper §2/§2.1.)

* Mini-batch SGD — :class:`MinibatchSGD`, the MLlib
  ``LinearRegressionWithSGD`` stand-in (paper §5.4, Fig 5): row-sampled
  gradient steps on the primal with a 1/sqrt(t) step-size schedule.
  ``run()`` is the legacy single-device loop; ``run_workers()`` /
  ``run_sharded()`` are the distributed drivers with row-partitioned
  data and an n-dimensional gradient all-reduce — note this is *more*
  traffic than CoCoA's m-vector whenever n > m, one of the reasons
  CoCoA wins (§5.4), and it is visible in the sharded HLO.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import distributed as dist
from repro.core.glm import GLMProblem, optimal_objective, primal_objective, suboptimality
from repro.core.cocoa import CoCoAConfig, CoCoATrainer, History
from repro.utils import compat


class MinibatchSCD(CoCoATrainer):
    """First-class mini-batch SCD (the paper's §2.1 baseline).

    CoCoA's partitioning, both execution drivers, and all three comm
    schemes — with the fixed-residual local solver and 1/sigma-damped
    aggregation. Constructing one forces ``solver="scd_fixed"`` so the
    baseline cannot silently run CoCoA's immediate-local-update solver.
    """

    def __init__(self, cfg: CoCoAConfig, A: np.ndarray, b: np.ndarray):
        if cfg.solver != "scd_fixed":
            cfg = dataclasses.replace(cfg, solver="scd_fixed")
        super().__init__(cfg, A, b)


@dataclass(frozen=True)
class SGDConfig:
    batch_frac: float = 1.0          # MLlib miniBatchFraction
    step_size: float = 1.0           # base step (gamma / sqrt(t) schedule)
    lam: float = 1.0
    eta: float = 1.0
    K: int = 8
    H: int = 1                       # local SGD steps per round (H=1: MLlib)
    seed: int = 0
    # the unified exchange surface (see distributed.ExchangeConfig for
    # the spec grammar); the string knobs below are deprecated aliases
    exchange: "dist.ExchangeConfig | str | None" = None
    comm_scheme: str | None = None   # DEPRECATED alias -> exchange
    exchange_mode: str | None = None  # DEPRECATED alias -> exchange

    def __post_init__(self):
        # fold everything into ONE validated ExchangeConfig (fail loudly
        # on typos) and store the canonical values back so
        # dataclasses.replace(cfg, ...) round-trips silently
        ex = dist.resolve_exchange(self.exchange,
                                   comm_scheme=self.comm_scheme,
                                   exchange_mode=self.exchange_mode,
                                   owner=type(self).__name__)
        object.__setattr__(self, "exchange", ex)
        object.__setattr__(self, "comm_scheme", ex.scheme.name)
        object.__setattr__(self, "exchange_mode", ex.mode.spec)
        if self.H < 1:
            raise ValueError(f"H must be >= 1, got {self.H}")


class _SGDRound:
    """Mini-batch SGD's plug into the generic round drivers: each worker
    owns a row block, samples a local mini-batch, and contributes an
    n-dimensional partial gradient to the all-reduce; the step-size
    schedule and the l1 proximal step run on the aggregated gradient.

    With ``H > 1`` the round is *local SGD* (the local-updates line the
    paper's trade-off generalizes to): each worker takes H proximal
    steps on a private model copy — its partial gradient scaled by K
    stands in for the full gradient — and the all-reduced quantity is
    the model delta, averaged by ``apply_update``. ``H=1`` keeps the
    exact MLlib-style single aggregated step (bit-identical RNG and
    float order), so the default path is unchanged."""

    # SGD's aggregate is a MEAN over workers (the /K in apply_update for
    # local SGD, the full-gradient estimate for H=1), so under elastic
    # membership the drivers rescale the summed update by K / K_live —
    # the average over the workers that actually contributed. (CoCoA's
    # aggregate is an unscaled SUM of residual deltas; rescaling it
    # would break the w = A@alpha - b invariant, so _CoCoARound leaves
    # this flag unset.)
    live_reweight = True

    def __init__(self, cfg: SGDConfig, problem: GLMProblem,
                 m_local: int, batch_local: int):
        self.cfg, self.problem = cfg, problem
        self.m_local, self.batch_local = m_local, batch_local
        self.scale = m_local / batch_local

    def _partial_grad(self, A_k, b_k, alpha, key):
        rows = jax.random.choice(key, self.m_local,
                                 shape=(self.batch_local,), replace=False)
        A_s, b_s = A_k[rows], b_k[rows]
        resid = A_s @ alpha - b_s
        return (A_s.T @ resid) * self.scale

    def _prox_step(self, alpha, grad, lr):
        alpha_new = alpha - lr * grad
        # L1 proximal step for the elastic-net case.
        thresh = lr * self.cfg.lam * (1.0 - self.cfg.eta)
        return jnp.sign(alpha_new) * jnp.maximum(
            jnp.abs(alpha_new) - thresh, 0.0)

    def local_step(self, data_k, local_k, alpha, key, t):
        cfg = self.cfg
        A_k, b_k = data_k                 # (m_local, n), (m_local,)
        if cfg.H == 1:
            return self._partial_grad(A_k, b_k, alpha, key), local_k
        lr = cfg.step_size / jnp.sqrt(jnp.asarray(t, jnp.float32))

        def body(alpha_loc, key_h):
            # K x the partial gradient ~= the full gradient from this
            # worker's rows alone (exact in expectation under uniform
            # row partitioning)
            g = (cfg.K * self._partial_grad(A_k, b_k, alpha_loc, key_h)
                 + cfg.lam * cfg.eta * alpha_loc)
            return self._prox_step(alpha_loc, g, lr), None

        alpha_H, _ = jax.lax.scan(body, alpha,
                                  jax.random.split(key, cfg.H))
        return alpha_H - alpha, local_k

    def apply_update(self, alpha, total, t):
        cfg = self.cfg
        if cfg.H > 1:
            # total is the summed model delta: average the H-step local
            # models (the classic local-SGD combiner)
            return alpha + total / cfg.K
        grad = total + cfg.lam * cfg.eta * alpha
        lr = cfg.step_size / jnp.sqrt(jnp.asarray(t, jnp.float32))
        return self._prox_step(alpha, grad, lr)

    def local_metric(self, data_k, local_k, alpha_new):
        A_k, b_k = data_k                 # zero-padded rows contribute 0
        r = A_k @ alpha_new - b_k
        return 0.5 * jnp.sum(r * r)

    def finalize_metric(self, alpha_new, loss_sum):
        return loss_sum + self.problem.regularizer(alpha_new)


class MinibatchSGD:
    """MLlib-style distributed mini-batch SGD for elastic-net regression."""

    def __init__(self, cfg: SGDConfig, A: np.ndarray, b: np.ndarray):
        self.cfg = cfg
        self.A_np = np.asarray(A, np.float32)
        self.b_np = np.asarray(b, np.float32)
        self.A = jnp.asarray(self.A_np)
        self.b = jnp.asarray(self.b_np)
        self.m, self.n = A.shape
        self.problem = GLMProblem(lam=cfg.lam, eta=cfg.eta)
        self.exchange = cfg.exchange
        self.scheme = self.exchange.scheme
        self.mode = self.exchange.mode
        self.batch = max(1, int(cfg.batch_frac * self.m))
        self._step = self._build_step()
        self.m_local = -(-self.m // cfg.K)
        self.batch_local = max(1, int(round(cfg.batch_frac * self.m_local)))
        self._dist_state = None  # (data, algo, round_fn), built lazily
        self._p_star_cache: float | None = None

    def _distributed(self):
        """Row partition + round drivers, built on first use: the legacy
        single-device ``run()`` path must not pay for a second padded
        copy of A it never touches."""
        if self._dist_state is None:
            cfg, m_local = self.cfg, self.m_local
            # K zero-padded row blocks (padded rows are all-zero in A
            # and b, so they add 0 to both the gradient and the loss)
            A_pad = np.zeros((m_local * cfg.K, self.n), np.float32)
            A_pad[: self.m] = np.asarray(self.A, np.float32)
            b_pad = np.zeros((m_local * cfg.K,), np.float32)
            b_pad[: self.m] = np.asarray(self.b, np.float32)
            data = (jnp.asarray(A_pad.reshape(cfg.K, m_local, self.n)),
                    jnp.asarray(b_pad.reshape(cfg.K, m_local)))
            algo = _SGDRound(cfg, self.problem, m_local, self.batch_local)
            round_fn = dist.build_virtual_round(algo, self.exchange, data,
                                                K=cfg.K)
            self._dist_state = (data, algo, round_fn)
        return self._dist_state

    @property
    def _data(self):
        return self._distributed()[0]

    @property
    def _algo(self):
        return self._distributed()[1]

    @property
    def _round_fn(self):
        return self._distributed()[2]

    # ------------------------------------------------------------------
    @property
    def p_star(self) -> float:
        if self._p_star_cache is None:
            self._p_star_cache = optimal_objective(
                self.problem, np.asarray(self.A), np.asarray(self.b))
        return self._p_star_cache

    @property
    def p_zero(self) -> float:
        return float(self.problem.loss(-self.b))

    def init_state(self):
        """(local, shared) for the distributed drivers: SGD keeps no
        per-worker persistent state, so ``local`` is an empty block
        (widened with the per-worker residual over the n-length
        gradient under a stateful ``ef:`` codec). Stale mode widens the
        shared slot to (alpha, pending gradient)."""
        local = jnp.zeros((self.cfg.K, 0), jnp.float32)
        local = dist.wrap_local_state(self.exchange, local, self.n,
                                      self.cfg.K)
        alpha = jnp.zeros(self.n, jnp.float32)
        return local, dist.init_exchange_state(self.exchange, alpha)

    def with_H(self, H: int) -> "MinibatchSGD":
        """Fresh trainer with the local-update count moved (the H-sweep
        clone hook shared with the CoCoA-family trainers)."""
        return type(self)(dataclasses.replace(self.cfg, H=int(H)),
                          self.A_np, self.b_np)

    def comm_bytes_per_round(self, t: int | None = None) -> int:
        """Modelled bytes through the master per round: the n-vector
        gradient all-reduce + parameter broadcast across K workers,
        sized to the dtypes the collectives actually move (int8 gradient
        + f32 scale under ``compressed``, f32 otherwise). ``t`` asks for
        a specific 1-based round under the elastic membership schedule
        (dropped workers ship nothing; ``None`` = all K live)."""
        K_live = (None if t is None
                  else self.exchange.membership.live_count(t, self.cfg.K))
        return self.scheme.bytes_per_round(self.n, self.cfg.K,
                                           K_live=K_live,
                                           backend=self.exchange.backend)

    # ------------------------------------------------------------------
    # legacy single-device loop (global row sampling)
    # ------------------------------------------------------------------
    def _build_step(self):
        cfg, A, b, batch = self.cfg, self.A, self.b, self.batch

        @jax.jit
        def step(alpha, t, key):
            rows = jax.random.choice(key, A.shape[0], shape=(batch,),
                                     replace=False)
            A_s, b_s = A[rows], b[rows]
            resid = A_s @ alpha - b_s
            grad = (A_s.T @ resid) * (self.m / batch) + cfg.lam * cfg.eta * alpha
            lr = cfg.step_size / jnp.sqrt(t.astype(jnp.float32))
            alpha_new = alpha - lr * grad
            # L1 proximal step for the elastic-net case.
            thresh = lr * cfg.lam * (1.0 - cfg.eta)
            alpha_new = jnp.sign(alpha_new) * jnp.maximum(
                jnp.abs(alpha_new) - thresh, 0.0)
            return alpha_new

        return step

    def run(self, rounds: int, p_star: float | None = None,
            p_zero: float | None = None, record_every: int = 10,
            target_eps: float | None = None) -> History:
        if self.mode.stale:
            # the legacy single-device loop has no exchange to delay;
            # silently running it synchronously would mislabel the
            # trajectory (the knob must fail loudly, like a typo'd
            # scheme would)
            raise ValueError(
                "exchange_mode='stale' has no meaning for the legacy "
                "single-device run(); use run_workers() or run_sharded()")
        p_star = self.p_star if p_star is None else p_star
        p_zero = self.p_zero if p_zero is None else p_zero
        alpha = jnp.zeros(self.n, jnp.float32)
        key = jax.random.key(self.cfg.seed)
        hist = History(p_star=p_star, p_zero=p_zero)
        for t in range(1, rounds + 1):
            key, sub = jax.random.split(key)
            alpha = self._step(alpha, jnp.asarray(t), sub)
            if t % record_every == 0 or t == rounds:
                p = float(primal_objective(self.problem, self.A, self.b, alpha))
                hist.rounds.append(t)
                hist.primal.append(p)
                s = suboptimality(p, p_star, p_zero)
                hist.subopt.append(s)
                if target_eps is not None and s <= target_eps:
                    break
        self.alpha_final = np.asarray(alpha)
        return hist

    # ------------------------------------------------------------------
    # distributed drivers (row-partitioned, per-worker sampling)
    # ------------------------------------------------------------------
    def _record_loop(self, round_fn, local, alpha, rounds, record_every,
                     target_eps, p_star, p_zero) -> History:
        key = jax.random.key(self.cfg.seed)
        hist = History(p_star=self.p_star if p_star is None else p_star,
                       p_zero=self.p_zero if p_zero is None else p_zero)
        last_t = 0
        for t in range(1, rounds + 1):
            last_t = t
            key, sub = jax.random.split(key)
            local, alpha, primal = round_fn(local, alpha, sub, t)
            if t % record_every == 0 or t == rounds:
                p = float(primal)
                s = suboptimality(p, hist.p_star, hist.p_zero)
                hist.rounds.append(t)
                hist.primal.append(p)
                hist.subopt.append(s)
                if target_eps is not None and s <= target_eps:
                    break
        # stale runs carry one unapplied aggregate; absorb it so the
        # final iterate reflects every round that was computed
        alpha = dist.finish_run(round_fn, alpha, last_t)
        self.alpha_final = np.asarray(alpha)
        return hist

    def run_workers(self, rounds: int, record_every: int = 10,
                    target_eps: float | None = None,
                    p_star: float | None = None,
                    p_zero: float | None = None) -> History:
        """K virtual workers (vmap over the worker axis) — same math as
        ``run_sharded`` with the communication mechanics elided."""
        local, alpha = self.init_state()
        return self._record_loop(self._round_fn, local, alpha, rounds,
                                 record_every, target_eps, p_star, p_zero)

    def build_sharded_round(self, mesh: Mesh):
        """Distributed round via the generic shard_map driver; K must
        equal the mesh axis size. Returns jitted
        ``round_fn(local, alpha, key, t)``."""
        assert mesh.devices.size == self.cfg.K, (mesh.devices.size, self.cfg.K)
        return dist.build_sharded_round(self._algo, self.exchange,
                                        self._data, mesh)

    def run_sharded(self, rounds: int, mesh: Mesh | None = None,
                    record_every: int = 10,
                    target_eps: float | None = None,
                    p_star: float | None = None,
                    p_zero: float | None = None) -> History:
        if mesh is None:
            mesh = compat.make_mesh((self.cfg.K,), ("workers",))
        round_fn = self.build_sharded_round(mesh)
        local, alpha = dist.place_state(mesh, *self.init_state())
        return self._record_loop(round_fn, local, alpha, rounds,
                                 record_every, target_eps, p_star, p_zero)
