"""Baselines the paper compares against.

* Mini-batch SCD (SDCA-style, no immediate local updates): available via
  ``CoCoAConfig(solver="scd_fixed")`` — identical coordinate rule to
  CoCoA's local solver but every step sees the round-start residual and
  aggregation is damped by 1/sigma. (Paper §2/§2.1.)

* Mini-batch SGD — the MLlib ``LinearRegressionWithSGD`` stand-in
  (paper §5.4, Fig 5): row-sampled gradient steps on the primal with a
  1/sqrt(t) step-size schedule, gradients all-reduced across workers
  (an n-dimensional vector — note this is *more* traffic than CoCoA's
  m-vector whenever n > m, one of the reasons CoCoA wins).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.glm import GLMProblem, primal_objective, suboptimality
from repro.core.cocoa import History


@dataclass(frozen=True)
class SGDConfig:
    batch_frac: float = 1.0     # MLlib miniBatchFraction
    step_size: float = 1.0      # base step (gamma / sqrt(t) schedule)
    lam: float = 1.0
    eta: float = 1.0
    K: int = 8
    seed: int = 0


class MinibatchSGD:
    """MLlib-style distributed mini-batch SGD for elastic-net regression."""

    def __init__(self, cfg: SGDConfig, A: np.ndarray, b: np.ndarray):
        self.cfg = cfg
        self.A = jnp.asarray(A, jnp.float32)
        self.b = jnp.asarray(b, jnp.float32)
        self.m, self.n = A.shape
        self.problem = GLMProblem(lam=cfg.lam, eta=cfg.eta)
        self.batch = max(1, int(cfg.batch_frac * self.m))
        self._step = self._build_step()

    def _build_step(self):
        cfg, A, b, batch = self.cfg, self.A, self.b, self.batch

        @jax.jit
        def step(alpha, t, key):
            rows = jax.random.choice(key, A.shape[0], shape=(batch,),
                                     replace=False)
            A_s, b_s = A[rows], b[rows]
            resid = A_s @ alpha - b_s
            grad = (A_s.T @ resid) * (self.m / batch) + cfg.lam * cfg.eta * alpha
            lr = cfg.step_size / jnp.sqrt(t.astype(jnp.float32))
            alpha_new = alpha - lr * grad
            # L1 proximal step for the elastic-net case.
            thresh = lr * cfg.lam * (1.0 - cfg.eta)
            alpha_new = jnp.sign(alpha_new) * jnp.maximum(
                jnp.abs(alpha_new) - thresh, 0.0)
            return alpha_new

        return step

    def comm_bytes_per_round(self, itemsize: int = 8) -> int:
        # gradient all-reduce (n) + parameter broadcast (n), K workers
        return 2 * self.cfg.K * self.n * itemsize

    def run(self, rounds: int, p_star: float, p_zero: float,
            record_every: int = 10, target_eps: float | None = None) -> History:
        alpha = jnp.zeros(self.n, jnp.float32)
        key = jax.random.key(self.cfg.seed)
        hist = History(p_star=p_star, p_zero=p_zero)
        for t in range(1, rounds + 1):
            key, sub = jax.random.split(key)
            alpha = self._step(alpha, jnp.asarray(t), sub)
            if t % record_every == 0 or t == rounds:
                p = float(primal_objective(self.problem, self.A, self.b, alpha))
                hist.rounds.append(t)
                hist.primal.append(p)
                s = suboptimality(p, p_star, p_zero)
                hist.subopt.append(s)
                if target_eps is not None and s <= target_eps:
                    break
        self.alpha_final = np.asarray(alpha)
        return hist
