"""Unified distributed-driver layer: one communication substrate for all
three algorithms (paper §5.3/§5.4).

The paper's headline result comes from applying the *same* framework and
algorithmic optimizations to three distributed linear ML algorithms —
CoCoA, mini-batch SCD, and mini-batch SGD. That comparison is only
meaningful when every algorithm runs under the same communication
substrate, so this module factors it out:

  * :class:`CommScheme` — a *transport* (which collective moves the
    update) composed with an *update codec* (what one worker's update
    looks like on the wire, ``repro.comm``). Transports:

      - ``persistent``      per-worker state lives on its worker across
        rounds (the paper's "persistent local memory" / (B)*, (D)*
        optimization); the aggregate travels via an in-place ``psum``.
      - ``spark_faithful``  everything is shipped through the master
        every round: updates are collected (all-gather) and summed
        locally instead of psum'd, and per-worker persistent state is
        all-gathered and re-sliced — mathematically the identity, but
        the extra collective traffic is real and visible in the HLO.
      - ``compressed``      beyond-paper: each worker's update is
        codec-encoded before the all-gather and decoded + summed
        locally. The codec is named after a colon — ``compressed:int8``
        (absmax int8 + f32 scale, 4x less traffic than f32),
        ``compressed:int4`` (two elements per byte, ~8x), or
        ``compressed:f32`` (the identity codec — the bare transport).
        Bare ``"compressed"`` aliases ``compressed:int8``, so every
        pre-codec config keeps its exact behavior.
      - ``reduce_scatter``  beyond-paper: the update exchange as an
        explicit ``psum_scatter`` + ``all_gather`` pair (the classic
        ring decomposition of all-reduce) — each worker moves only
        2·(K-1)/K of the update vector each way instead of the full
        vector, the cheapest exact f32 exchange on a ring.

    Both execution drivers call the ONE codec object (so they cannot
    drift) and the byte accounting is sized to what the collectives
    actually move (``codec.wire_bytes`` per worker each way — int8/int4
    payloads + the 4-byte scale under ``compressed``, f32 otherwise).

  * :class:`ExchangeMode` — the *staleness* axis, orthogonal to the
    scheme (paper §4-§5: Spark's scheduling delay makes workers compute
    against stale state; treating that delay as an algorithmic knob is
    the other half of the computation/communication trade-off):

      - ``sync``   bulk-synchronous: the round-``t`` aggregate is
        applied before round ``t+1`` computes (every scheme above, as
        in the paper's optimized implementations).
      - ``stale``  one-round-delayed apply: workers compute round ``t``
        against shared state that has only absorbed aggregates through
        round ``t-2``; the round-``t-1`` aggregate is carried as
        explicit *pending* state and applied while round ``t`` computes.
        The collective still runs every round (same wire bytes, same
        HLO traffic), but nothing waits on it — the exchange can hide
        behind the next round's compute, which is exactly the overlap
        the trade-off layer's ``TimeModel`` charges for.

  * generic round drivers over the ``workers`` mesh axis — a *virtual*
    driver (vmap/lax.map over stacked ``(K, ...)`` worker arrays on
    however many real devices exist) and a *sharded* driver (real
    distribution via ``shard_map`` with explicit collectives). An
    algorithm plugs in via the :class:`RoundAlgorithm` protocol; the
    same object drives both paths, so the math can only differ in
    communication mechanics.

Per-worker RNG is derived identically in both drivers (``split`` of the
round key into K worker keys) and is untouched by the exchange mode, so
a virtual and a sharded run with the same seed follow the same
trajectory up to reduction-order float jitter — in either mode.

Under ``stale`` the drivers' ``shared`` slot widens to the pair
``(shared, pending)`` (build it with :func:`init_exchange_state`); a
finished run flushes the last pending aggregate with ``round_fn.flush``
so a 1-round stale run produces the same iterate as a sync run (the
delayed apply is a pipeline shift, not a lost update).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Protocol

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm import UpdateCodec, get_codec
from repro.utils import compat

# the transports; ``compressed`` composes with a codec suffix — the
# canonical sweep set keeps the bare aliases (compressed == :int8)
COMM_TRANSPORTS = ("persistent", "spark_faithful", "compressed",
                   "reduce_scatter")
COMM_SCHEMES = COMM_TRANSPORTS
EXCHANGE_MODES = ("sync", "stale")

FP_ITEMSIZE = 4        # every dense array in the system is float32


# ---------------------------------------------------------------------------
# back-compat shims for the pre-codec quantizer API — the single int8
# source of truth now lives in repro.comm.codec; both drivers reach it
# through the scheme's codec object
# ---------------------------------------------------------------------------
def quantize_update(dv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absmax int8 quantization of one worker's update vector
    (``Int8Codec.encode``: the jnp oracle off TPU, the fused Pallas
    quantize+pack kernel on TPU).

    Returns ``(q, scale)`` with ``q`` int8 in [-127, 127] and ``scale``
    a scalar f32 such that ``dequantize_update(q, scale) ~= dv``.
    """
    return get_codec("int8").encode(dv)


def dequantize_update(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# communication schemes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CommScheme:
    """One of the paper's communication schemes (§5.3) as transport x
    codec — ``name`` is ``"<transport>"`` or ``"compressed:<codec>"``
    (bare ``"compressed"`` aliases ``compressed:int8``). Carries both
    the collective mechanics (used inside the round drivers) and the
    byte accounting for the overhead model, so modelled traffic cannot
    drift from what is actually moved.
    """
    name: str

    def __post_init__(self):
        transport, _, codec = self.name.partition(":")
        if transport not in COMM_TRANSPORTS:
            raise ValueError(f"unknown comm scheme {self.name!r}; "
                             f"known transports: {COMM_TRANSPORTS} "
                             f"(codecs compose as 'compressed:<codec>')")
        if codec:
            if transport != "compressed":
                raise ValueError(
                    f"comm scheme {self.name!r}: only the 'compressed' "
                    f"transport takes a codec suffix ('{transport}' "
                    f"moves exact f32 by construction)")
            get_codec(codec)  # raises on unknown codec names

    @property
    def transport(self) -> str:
        return self.name.partition(":")[0]

    @property
    def codec(self) -> UpdateCodec:
        """The wire codec this scheme's exchange runs through: the named
        one for ``compressed`` (int8 when bare — the pre-codec default),
        the f32 identity for every exact-f32 transport."""
        transport, _, codec = self.name.partition(":")
        if transport == "compressed":
            return get_codec(codec or "int8")
        return get_codec("f32")

    @property
    def persistent_local_state(self) -> bool:
        """May per-worker state (e.g. alpha_[k]) stay device-resident?"""
        return self.transport != "spark_faithful"

    # -- aggregation inside shard_map (per-shard view) ---------------------
    def all_reduce(self, update: jax.Array, axis: str) -> jax.Array:
        """Sum the per-worker 1-D update across the mesh axis."""
        if self.transport == "compressed":
            parts = self.codec.encode(update)       # e.g. ((L,) int8, scale)
            gathered = tuple(lax.all_gather(p, axis) for p in parts)
            return jnp.sum(
                self.codec.decode_stacked(gathered, update.shape[0]),
                axis=0)
        if self.name == "spark_faithful":
            # collected at the master and re-broadcast, not reduced
            # in-place — identity, but the traffic is real.
            return jnp.sum(lax.all_gather(update, axis), axis=0)
        if self.name == "reduce_scatter":
            # explicit ring decomposition: reduce-scatter the (padded)
            # update so each worker owns one reduced L/K segment, then
            # all-gather the segments back. lax.psum(1, axis) folds to
            # the static axis size, so the pad amount is concrete.
            L = update.shape[0]
            K = lax.psum(1, axis)
            pad = -L % K
            if pad:
                update = jnp.concatenate(
                    [update, jnp.zeros((pad,), update.dtype)])
            seg = lax.psum_scatter(update, axis, tiled=True)
            return lax.all_gather(seg, axis, tiled=True)[:L]
        return lax.psum(update, axis)

    # -- aggregation over stacked (K, L) updates (virtual driver) ----------
    def all_reduce_stacked(self, updates: jax.Array) -> jax.Array:
        if self.transport == "compressed":
            parts = jax.vmap(self.codec.encode)(updates)
            return jnp.sum(
                self.codec.decode_stacked(parts, updates.shape[1]),
                axis=0)
        return jnp.sum(updates, axis=0)

    # -- persistent-state round trip (sharded driver only) -----------------
    def roundtrip_local_state(self, state: jax.Array, axis: str) -> jax.Array:
        """``spark_faithful`` ships per-worker persistent state through
        the master every round: all-gather, then each worker re-slices
        its own block — the identity, with real collective traffic."""
        if self.persistent_local_state or state.size == 0:
            return state
        gathered = lax.all_gather(state, axis)      # (K, L_local)
        return lax.dynamic_index_in_dim(gathered, lax.axis_index(axis), 0,
                                        keepdims=False)

    # -- modelled traffic --------------------------------------------------
    def bytes_per_round(self, update_len: int, K: int,
                        local_state_len: int = 0) -> int:
        """Bytes on the wire per round (paper Fig 1 + §5.3), sized to
        the dtypes the collectives actually move.

        Master-centric schemes: K workers send their codec-encoded
        ``update_len``-vector up and receive the aggregate back —
        ``codec.wire_bytes`` per worker each way (f32 4B/element for
        the exact transports; int8 1B/element or int4 packed
        ceil(len/2) bytes, + the 4-byte f32 scale, under
        ``compressed``). ``spark_faithful`` additionally ships the
        ``local_state_len`` total elements of per-worker persistent
        state up and down in f32. ``reduce_scatter`` has no master:
        each worker moves (K-1)/K of the (K-padded) update each way on
        the ring — ``2*(K-1)*len_pad*4`` bytes in total.
        """
        if self.transport == "reduce_scatter":
            len_pad = -(update_len // -K) * K
            return 2 * (K - 1) * len_pad * FP_ITEMSIZE
        v = 2 * K * self.codec.wire_bytes(update_len)
        a = (0 if self.persistent_local_state
             else 2 * local_state_len * FP_ITEMSIZE)
        return v + a


def get_scheme(name: str) -> CommScheme:
    """Validated scheme lookup (raises on typos instead of silently
    falling through to persistent behavior)."""
    return CommScheme(name)


# ---------------------------------------------------------------------------
# exchange modes (the staleness axis, orthogonal to the comm scheme)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExchangeMode:
    """``sync`` (bulk-synchronous apply) or ``stale`` (one-round-delayed
    apply: the aggregate computed in round ``t`` is applied during round
    ``t+1`` while workers compute against the unapplied state — the
    paper's Spark scheduling-delay regime as an explicit knob)."""
    name: str

    def __post_init__(self):
        if self.name not in EXCHANGE_MODES:
            raise ValueError(f"unknown exchange mode {self.name!r}; "
                             f"known: {EXCHANGE_MODES}")

    @property
    def stale(self) -> bool:
        return self.name == "stale"


def get_mode(mode: "ExchangeMode | str") -> ExchangeMode:
    """Validated mode lookup (raises on typos instead of silently
    running bulk-synchronous rounds)."""
    return mode if isinstance(mode, ExchangeMode) else ExchangeMode(mode)


def init_exchange_state(mode: "ExchangeMode | str", shared,
                        pending=None):
    """The drivers' ``shared`` slot for the given mode: ``sync`` passes
    the shared state through untouched; ``stale`` pairs it with the
    carried pending aggregate (zeros until round 1 has aggregated —
    every algorithm here all-reduces an update shaped like its shared
    state, so ``zeros_like(shared)`` is the default template)."""
    if not get_mode(mode).stale:
        return shared
    if pending is None:
        pending = jax.tree_util.tree_map(jnp.zeros_like, shared)
    return (shared, pending)


def _delayed_apply(algo: "RoundAlgorithm", shared, pending, t):
    """Apply the round-``t-1`` pending aggregate under its own round
    index. Round 1 has no real pending aggregate (only the zero init),
    and an algorithm's ``apply_update`` need not be the identity on a
    zero update (e.g. SGD's proximal step still moves), so the round-1
    apply is masked out rather than trusted to be a no-op."""
    applied = algo.apply_update(shared, pending, jnp.maximum(t - 1, 1))
    return jax.tree_util.tree_map(
        lambda a, s: jnp.where(t <= 1, s, a), applied, shared)


def _make_flush(algo: "RoundAlgorithm", mode: ExchangeMode) -> Callable:
    """``flush(shared_state, t) -> shared``: absorb the pending
    aggregate left over from the last executed round ``t`` (identity in
    sync mode). Without the flush a 1-round stale run would silently
    drop its only update — the off-by-one the single-round
    sync-vs-stale regression test pins."""
    if not mode.stale:
        return lambda shared, t: shared

    @jax.jit
    def flush(shared_state, t):
        shared, pending = shared_state
        return algo.apply_update(shared, pending, t)

    return flush


def finish_run(round_fn: Callable, shared, last_t: int):
    """The one post-run epilogue every trainer loop shares: absorb the
    pending aggregate from the last executed round (``last_t`` is its
    1-based index; 0 means no round ran, so there is nothing pending
    and the bare shared state is unwrapped as-is)."""
    if last_t > 0:
        return round_fn.flush(shared, last_t)
    return shared[0] if round_fn.mode.stale else shared


# ---------------------------------------------------------------------------
# the algorithm protocol
# ---------------------------------------------------------------------------
class RoundAlgorithm(Protocol):
    """What one algorithm plugs into the generic round drivers.

    ``data``   tuple of ``(K, ...)`` stacked arrays, partitioned on the
               leading worker axis (column blocks for CoCoA/SCD, row
               blocks for SGD).
    ``local``  ``(K, L_local)`` per-worker persistent state (alpha
               blocks; empty ``(K, 0)`` when the algorithm has none).
    ``shared`` replicated state (the residual ``w`` / the model
               ``alpha``).
    """

    def local_step(self, data_k, local_k, shared, key, t):
        """One worker's round: returns ``(update, local_new)`` where
        ``update`` is the 1-D vector to be all-reduced."""
        ...

    def apply_update(self, shared, total_update, t):
        """New shared state from the all-reduced update (round ``t``)."""
        ...

    def local_metric(self, data_k, local_k, shared_new):
        """Per-worker scalar metric contribution (summed across workers)."""
        ...

    def finalize_metric(self, shared_new, metric_sum):
        """Round metric (e.g. the primal objective) from the summed
        per-worker contributions."""
        ...


# ---------------------------------------------------------------------------
# generic round drivers
# ---------------------------------------------------------------------------
def build_virtual_round(algo: RoundAlgorithm, scheme: CommScheme, data,
                        *, K: int, use_map: bool = False,
                        mode: "ExchangeMode | str" = "sync") -> Callable:
    """K *virtual* workers on however many real devices exist.

    Returns jitted ``round_fn(local, shared, key, t) -> (local_new,
    shared_new, metric)``. ``use_map`` runs workers with ``lax.map``
    instead of ``vmap`` (needed for interpret-mode Pallas solvers).
    Under ``mode="stale"`` the ``shared`` slot is the
    ``(shared, pending)`` pair from :func:`init_exchange_state`:
    workers compute against the pre-apply state, the previous round's
    pending aggregate is applied alongside, and this round's aggregate
    rides out as the new pending. ``round_fn.flush`` absorbs the final
    pending aggregate after the last round.
    """
    mode = get_mode(mode)

    @jax.jit
    def round_fn(local, shared, key, t=1):
        if mode.stale:
            shared, pending = shared
        keys = jax.random.split(key, K)
        if use_map:
            upd, local_new = lax.map(
                lambda args: algo.local_step(args[0], args[1], shared,
                                             args[2], t),
                (data, local, keys))
        else:
            upd, local_new = jax.vmap(
                lambda d, l, k: algo.local_step(d, l, shared, k, t))(
                    data, local, keys)
        total = scheme.all_reduce_stacked(upd)
        if mode.stale:
            shared_new = _delayed_apply(algo, shared, pending, t)
            shared_out = (shared_new, total)
            # the metric must be the objective of ONE iterate: pair the
            # shared state absorbed through round t-1 with the ROUND-t-1
            # local state (for CoCoA, w = A@alpha - b holds exactly for
            # that pair). Mixing in the round-t local state produces a
            # value that is no iterate's objective and can dip below
            # p_star. Under stale the recorded metric therefore lags
            # one round — the honest cost of the delayed apply.
            metric_local = local
        else:
            shared_new = algo.apply_update(shared, total, t)
            shared_out = shared_new
            metric_local = local_new
        metric_sum = jnp.sum(jax.vmap(
            lambda d, l: algo.local_metric(d, l, shared_new))(data,
                                                              metric_local))
        return local_new, shared_out, algo.finalize_metric(shared_new,
                                                           metric_sum)

    round_fn.mode = mode
    round_fn.flush = _make_flush(algo, mode)
    return round_fn


def build_sharded_round(algo: RoundAlgorithm, scheme: CommScheme, data,
                        mesh: Mesh, *, donate: bool = True,
                        mode: "ExchangeMode | str" = "sync") -> Callable:
    """Real distribution via ``shard_map`` over the mesh's single axis.

    Returns jitted ``round_fn(local, shared, key, t) -> (local_new,
    shared_new, metric)`` with ``local``/``shared`` donated. The mesh
    axis size must equal the worker count K (the leading dim of every
    ``data`` leaf and of ``local``). Under ``mode="stale"`` the
    ``shared`` slot is the ``(shared, pending)`` pair — same delayed
    apply, same collectives (the wire traffic is mode-independent,
    which the drivers benchmark asserts against the HLO), same
    per-worker RNG as the virtual driver.
    """
    mode = get_mode(mode)
    axis = mesh.axis_names[0]
    K = mesh.devices.size
    for leaf in jax.tree_util.tree_leaves(data):
        assert leaf.shape[0] == K, (leaf.shape, K)

    def shard_fn(data_sh, local_sh, keys_sh, shared, t):
        data_k = jax.tree_util.tree_map(lambda x: x[0], data_sh)
        local_k = local_sh[0]
        key_k = jax.random.wrap_key_data(keys_sh[0])
        if mode.stale:
            shared, pending = shared
        upd, local_new = algo.local_step(data_k, local_k, shared, key_k, t)
        total = scheme.all_reduce(upd, axis)
        if mode.stale:
            shared_new = _delayed_apply(algo, shared, pending, t)
            shared_out = (shared_new, total)
        else:
            shared_new = algo.apply_update(shared, total, t)
            shared_out = shared_new
        local_new = scheme.roundtrip_local_state(local_new, axis)
        # stale pairs the lagged shared state with the round-t-1 local
        # state so the metric is a real iterate's objective (see the
        # virtual driver) — and matches it round for round
        metric_local = local_k if mode.stale else local_new
        metric_sum = lax.psum(algo.local_metric(data_k, metric_local,
                                                shared_new), axis)
        metric = algo.finalize_metric(shared_new, metric_sum)
        return local_new[None], shared_out, metric

    data_specs = jax.tree_util.tree_map(lambda _: P(axis), data)
    sharded = compat.shard_map(
        shard_fn, mesh,
        in_specs=(data_specs, P(axis), P(axis), P(None), P()),
        out_specs=(P(axis), P(None), P()))

    @functools.partial(jax.jit, donate_argnums=(1, 2) if donate else ())
    def jitted(keys, local, shared, t):
        return sharded(data, local, keys, shared, t)

    def split_keys(key):
        # same per-worker key derivation as the virtual driver, so the
        # two paths follow the same trajectory; computed OUTSIDE the
        # jitted round so XLA does not partition the threefry split into
        # spurious u32 collectives (which would pollute the HLO traffic
        # the byte accounting is checked against)
        return jax.random.key_data(jax.random.split(key, K))

    def round_fn(local, shared, key, t=1):
        return jitted(split_keys(key), local, shared, t)

    # the jitted inner + key derivation, exposed for AOT lowering (HLO
    # collective-traffic inspection in benches/tests) and state placement
    round_fn.jitted = jitted
    round_fn.split_keys = split_keys
    round_fn.mesh = mesh
    round_fn.mode = mode
    round_fn.flush = _make_flush(algo, mode)
    return round_fn


def place_state(mesh: Mesh, local, shared, axis: str | None = None):
    """Device-put ``(local, shared)`` for the sharded driver: ``local``
    partitioned over the worker axis, ``shared`` replicated (``shared``
    may be the stale mode's ``(shared, pending)`` pair — every leaf is
    replicated)."""
    axis = axis or mesh.axis_names[0]
    local = jax.device_put(local, NamedSharding(mesh, P(axis)))
    shared = jax.device_put(shared, NamedSharding(mesh, P(None)))
    return local, shared
