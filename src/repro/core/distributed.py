"""Unified distributed-driver layer: one communication substrate for all
three algorithms (paper §5.3/§5.4).

The paper's headline result comes from applying the *same* framework and
algorithmic optimizations to three distributed linear ML algorithms —
CoCoA, mini-batch SCD, and mini-batch SGD. That comparison is only
meaningful when every algorithm runs under the same communication
substrate, so this module factors it out:

  * :class:`CommScheme` — a *transport* (which collective moves the
    update) composed with an *update codec* (what one worker's update
    looks like on the wire, ``repro.comm``). Transports:

      - ``persistent``      per-worker state lives on its worker across
        rounds (the paper's "persistent local memory" / (B)*, (D)*
        optimization); the aggregate travels via an in-place ``psum``.
      - ``spark_faithful``  everything is shipped through the master
        every round: updates are collected (all-gather) and summed
        locally instead of psum'd, and per-worker persistent state is
        all-gathered and re-sliced — mathematically the identity, but
        the extra collective traffic is real and visible in the HLO.
      - ``compressed``      beyond-paper: each worker's update is
        codec-encoded before the all-gather and decoded + summed
        locally. The codec is named after a colon — ``compressed:int8``
        (absmax int8 + f32 scale, 4x less traffic than f32),
        ``compressed:int4`` (two elements per byte, ~8x),
        ``compressed:int2`` (four per byte, ~16x),
        ``compressed:topk(r=..)`` (ship only the ceil(r*L) largest
        entries), or ``compressed:f32`` (the identity codec — the bare
        transport). Bare ``"compressed"`` aliases ``compressed:int8``,
        so every pre-codec config keeps its exact behavior. The
        ``ef:``-prefixed codecs (``compressed:ef:int4`` ...) add error
        feedback: the encode carries a per-worker residual between
        rounds, which widens the drivers' ``local`` slot to the
        ``(local, codec_state)`` pair (build it with
        :func:`wrap_local_state`) the same way ``stale`` widens
        ``shared``.
      - ``reduce_scatter``  beyond-paper: the update exchange as an
        explicit ``psum_scatter`` + ``all_gather`` pair (the classic
        ring decomposition of all-reduce) — each worker moves only
        2·(K-1)/K of the update vector each way instead of the full
        vector, the cheapest exact f32 exchange on a ring.

    Both execution drivers call the ONE codec object (so they cannot
    drift) and the byte accounting is sized to what the collectives
    actually move (``codec.wire_bytes`` per worker each way — int8/int4
    payloads + the 4-byte scale under ``compressed``, f32 otherwise).

    The collective *mechanics* under the transports live in
    ``repro.comm.collectives`` behind the pluggable
    :class:`~repro.comm.collectives.CollectiveBackend` axis (``xla``
    fused collectives vs an explicit ``ring`` of ``ppermute`` hops, the
    Alchemist-style fabric swap); :class:`ExchangeConfig` carries the
    backend name as its own spec segment (default ``xla``).

  * :class:`ExchangeMode` — the *staleness* axis, orthogonal to the
    scheme (paper §4-§5: Spark's scheduling delay makes workers compute
    against stale state; treating that delay as an algorithmic knob is
    the other half of the computation/communication trade-off):

      - ``sync``   bulk-synchronous: the round-``t`` aggregate is
        applied before round ``t+1`` computes (every scheme above, as
        in the paper's optimized implementations).
      - ``stale``  ``k``-round-bounded-delay apply (``stale`` is k=1,
        ``stale:k=2`` two rounds deep, ...): workers compute round
        ``t`` against shared state that has only absorbed aggregates
        through round ``t-1-k``; the last ``k`` aggregates travel as
        an explicit stacked *pending queue*, the oldest applied while
        round ``t`` computes. The collective still runs every round
        (same wire bytes, same HLO traffic), but nothing waits on it —
        the exchange can hide behind up to ``k`` rounds of compute,
        which is exactly the overlap the trade-off layer's
        ``TimeModel`` charges for.

  * :class:`StragglerProfile` — per-worker compute-jitter injection
    (the paper's straggling-executor regime, §4). Time-only by
    construction: under a bulk-synchronous barrier every round waits
    for its slowest worker, so the drivers ignore the profile
    numerically (trajectories and wire traffic are straggler-
    invariant — regression-tested) while ``TimeModel`` stretches
    compute by the expected barrier factor ``E[max over K workers]``.

  * :class:`MembershipSchedule` — elastic worker membership
    (``drop:1@5-9``): liveness is evaluated *in-graph* from the round
    index, so ONE compiled round serves every round. A dropped worker
    contributes an exact-zero update (zeroed before codec encode —
    zero is a fixed point of every codec), keeps its persistent local
    state frozen, and mean-style aggregates are reweighted by the
    live-worker count; the HLO collectives are membership-invariant,
    only the byte model's ``K_live`` changes.

  * :class:`ExchangeConfig` — all four of the above in one frozen
    value, round-tripping to/from a ``/``-separated spec string
    (``"compressed:int4/stale:k=2/straggler:mix(p=0.1,slow=8)/
    drop:1@5-9"``). This is the ONE surface configs, driver builders,
    ``TimeModel`` and ``sweep_H`` accept; the scattered
    ``comm_scheme=`` / ``exchange_mode=`` knobs are deprecated aliases
    that fold into it via :func:`resolve_exchange`.

  * generic round drivers over the ``workers`` mesh axis — a *virtual*
    driver (vmap/lax.map over stacked ``(K, ...)`` worker arrays on
    however many real devices exist) and a *sharded* driver (real
    distribution via ``shard_map`` with explicit collectives). An
    algorithm plugs in via the :class:`RoundAlgorithm` protocol; the
    same object drives both paths, so the math can only differ in
    communication mechanics.

Per-worker RNG is derived identically in both drivers (``split`` of the
round key into K worker keys) and is untouched by the exchange mode, so
a virtual and a sharded run with the same seed follow the same
trajectory up to reduction-order float jitter — in either mode, under
any membership schedule.

Under ``stale`` the drivers' ``shared`` slot widens to the pair
``(shared, queue)`` — a stacked ``(k, ...)`` pending leaf per shared
leaf (build it with :func:`init_exchange_state`); a finished run
flushes every still-pending aggregate with ``round_fn.flush`` so a
short stale run produces the same iterate as a sync run (the delayed
apply is a pipeline shift, not a lost update — pinned against a serial
replay in the tests).
"""
from __future__ import annotations

import functools
import re
from dataclasses import dataclass, field
from typing import Callable, Protocol

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm import UpdateCodec, get_codec
from repro.comm.collectives import (FP_ITEMSIZE, COLLECTIVE_BACKENDS,
                                    get_backend, exchange_all_reduce,
                                    exchange_roundtrip_state)
from repro.utils import compat
from repro.utils.deprecation import warn_deprecated

# the transports; ``compressed`` composes with a codec suffix — the
# canonical sweep set keeps the bare aliases (compressed == :int8)
COMM_TRANSPORTS = ("persistent", "spark_faithful", "compressed",
                   "reduce_scatter")
COMM_SCHEMES = COMM_TRANSPORTS
EXCHANGE_MODES = ("sync", "stale")
STRAGGLER_KINDS = ("none", "det", "lognormal", "mix")

# the one-line grammar every exchange-spec parse error points at
EXCHANGE_GRAMMAR = ("<transport>[:<codec>] | "
                    + " | ".join(COLLECTIVE_BACKENDS)
                    + " | sync | stale[:k=<int>] | "
                    "straggler:<kind>[(p=..,slow=..,sigma=..)] | "
                    "drop:<worker>@<round>[-<round>]")


# ---------------------------------------------------------------------------
# back-compat shims for the pre-codec quantizer API — the single int8
# source of truth now lives in repro.comm.codec; both drivers reach it
# through the scheme's codec object
# ---------------------------------------------------------------------------
def quantize_update(dv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absmax int8 quantization of one worker's update vector
    (``Int8Codec.encode``: the jnp oracle off TPU, the fused Pallas
    quantize+pack kernel on TPU).

    Returns ``(q, scale)`` with ``q`` int8 in [-127, 127] and ``scale``
    a scalar f32 such that ``dequantize_update(q, scale) ~= dv``.
    """
    return get_codec("int8").encode(dv)


def dequantize_update(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# communication schemes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CommScheme:
    """One of the paper's communication schemes (§5.3) as transport x
    codec — ``name`` is ``"<transport>"`` or ``"compressed:<codec>"``
    (bare ``"compressed"`` aliases ``compressed:int8``). Carries both
    the collective mechanics (used inside the round drivers) and the
    byte accounting for the overhead model, so modelled traffic cannot
    drift from what is actually moved.
    """
    name: str

    @classmethod
    def parse(cls, spec: "CommScheme | str") -> "CommScheme":
        """The canonical (non-deprecated) scheme lookup: a pass-through
        for :class:`CommScheme` instances, validated construction for
        ``"<transport>[:<codec>]"`` strings."""
        return spec if isinstance(spec, CommScheme) else cls(str(spec))

    def __post_init__(self):
        transport, _, codec = self.name.partition(":")
        if transport not in COMM_TRANSPORTS:
            raise ValueError(f"unknown comm scheme {self.name!r}; "
                             f"known transports: {COMM_TRANSPORTS} "
                             f"(codecs compose as 'compressed:<codec>')")
        if codec:
            if transport != "compressed":
                raise ValueError(
                    f"comm scheme {self.name!r}: only the 'compressed' "
                    f"transport takes a codec suffix ('{transport}' "
                    f"moves exact f32 by construction)")
            get_codec(codec)  # raises on unknown codec names

    @property
    def transport(self) -> str:
        return self.name.partition(":")[0]

    @property
    def codec(self) -> UpdateCodec:
        """The wire codec this scheme's exchange runs through: the named
        one for ``compressed`` (int8 when bare — the pre-codec default),
        the f32 identity for every exact-f32 transport."""
        transport, _, codec = self.name.partition(":")
        if transport == "compressed":
            return get_codec(codec or "int8")
        return get_codec("f32")

    @property
    def persistent_local_state(self) -> bool:
        """May per-worker state (e.g. alpha_[k]) stay device-resident?"""
        return self.transport != "spark_faithful"

    # -- aggregation inside shard_map (per-shard view) ---------------------
    def all_reduce(self, update: jax.Array, axis: str,
                   backend=None, state=None):
        """Sum the per-worker 1-D update across the mesh axis, moved by
        ``backend``'s collectives (name, backend object, or ``None`` for
        the fused ``xla`` fabric — ``repro.comm.collectives``).
        ``state`` is this worker's codec-state carry (the error-feedback
        residual): when given, the return value is ``(total,
        new_state)`` instead of the bare aggregate."""
        return exchange_all_reduce(self.transport, self.codec, update,
                                   axis, backend, state=state)

    # -- aggregation over stacked (K, L) updates (virtual driver) ----------
    def all_reduce_stacked(self, updates: jax.Array, state=None):
        """``state`` is the stacked ``(K, ...)`` per-worker codec-state
        carry; when given the encode runs through the codec's stateful
        entry point and the call returns ``(total, new_state)``."""
        if self.transport == "compressed":
            if state is None:
                parts = jax.vmap(self.codec.encode)(updates)
            else:
                parts, state = jax.vmap(
                    self.codec.encode_with_state)(updates, state)
            # fused decode+reduce, same method the sharded exchange
            # calls — the virtual/sharded bit-identity contract rides
            # on both drivers emitting the identical decode+sum HLO
            total = self.codec.decode_stacked_sum(parts,
                                                  updates.shape[1])
        else:
            total = jnp.sum(updates, axis=0)
        return total if state is None else (total, state)

    # -- persistent-state round trip (sharded driver only) -----------------
    def roundtrip_local_state(self, state: jax.Array, axis: str,
                              backend=None) -> jax.Array:
        """``spark_faithful`` ships per-worker persistent state through
        the master every round: all-gather, then each worker re-slices
        its own block — the identity, with real collective traffic."""
        if self.persistent_local_state or state.size == 0:
            return state
        return exchange_roundtrip_state(state, axis, backend)

    # -- modelled traffic --------------------------------------------------
    def bytes_per_round(self, update_len: int, K: int,
                        local_state_len: int = 0,
                        K_live: int | None = None,
                        backend=None) -> int:
        """Bytes on the wire per round (paper Fig 1 + §5.3), sized to
        the dtypes the collectives actually move — the backend owns the
        formula (:meth:`~repro.comm.collectives.CollectiveBackend.
        wire_bytes`), since the same transport moves different volumes
        on a fused collective vs an explicit ring.  ``K_live`` (elastic
        membership) is the number of live workers this round; ``None``
        (the default) means all K live, reproducing the pre-elastic
        formula bit for bit."""
        return get_backend(backend).wire_bytes(
            self.transport, self.codec, update_len, K,
            local_state_len=local_state_len, K_live=K_live)


def get_scheme(name: str) -> CommScheme:
    """Deprecated scheme lookup — use :meth:`CommScheme.parse` (or fold
    the scheme into a unified :class:`ExchangeConfig` spec)."""
    warn_deprecated(
        "get_scheme() is deprecated; use CommScheme.parse(spec) or the "
        "unified ExchangeConfig.parse(spec)")
    return CommScheme.parse(name)


# ---------------------------------------------------------------------------
# exchange modes (the staleness axis, orthogonal to the comm scheme)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExchangeMode:
    """``sync`` (bulk-synchronous apply) or ``stale`` (``k``-round-
    bounded-delay apply: the aggregate computed in round ``t`` is
    applied during round ``t+k`` while workers compute against state
    that has only absorbed aggregates through round ``t-1-k`` — the
    paper's Spark scheduling-delay regime as an explicit knob, now with
    the delay depth as a parameter). The canonical string spelling is
    ``"sync"``, ``"stale"`` (k=1), or ``"stale:k=<int>"``."""
    name: str
    k: int = 1

    @classmethod
    def parse(cls, spec: "ExchangeMode | str") -> "ExchangeMode":
        """The canonical (non-deprecated) mode lookup: a pass-through
        for :class:`ExchangeMode` instances, validated construction for
        ``"sync"`` / ``"stale"`` / ``"stale:k=<int>"`` strings."""
        if isinstance(spec, ExchangeMode):
            return spec
        name, _, opts = str(spec).partition(":")
        if name not in EXCHANGE_MODES:
            raise ValueError(f"unknown exchange mode {spec!r}; "
                             f"known: {EXCHANGE_MODES} (bounded "
                             f"staleness spells 'stale:k=<int>')")
        if not opts:
            return cls(name)
        m = re.fullmatch(r"k=([0-9]+)", opts)
        if name != "stale" or not m:
            raise ValueError(f"unknown exchange mode {spec!r}; the only "
                             f"parameterized spelling is 'stale:k=<int>' "
                             f"(e.g. 'stale:k=2')")
        return cls(name, int(m.group(1)))

    def __post_init__(self):
        if self.name not in EXCHANGE_MODES:
            raise ValueError(f"unknown exchange mode {self.name!r}; "
                             f"known: {EXCHANGE_MODES}")
        if self.k < 1:
            raise ValueError(f"exchange mode {self.name!r}: the staleness "
                             f"bound k must be >= 1, got {self.k}")
        if self.name == "sync" and self.k != 1:
            raise ValueError(f"exchange mode 'sync' takes no staleness "
                             f"bound (got k={self.k}); spell a bounded "
                             f"delay as 'stale:k={self.k}'")

    @property
    def stale(self) -> bool:
        return self.name == "stale"

    @property
    def spec(self) -> str:
        """Canonical string spelling (``parse(spec)`` round-trips)."""
        return self.name if self.k == 1 else f"{self.name}:k={self.k}"


def get_mode(mode: "ExchangeMode | str") -> ExchangeMode:
    """Deprecated mode lookup — use :meth:`ExchangeMode.parse` (or fold
    the mode into a unified :class:`ExchangeConfig` spec)."""
    warn_deprecated(
        "get_mode() is deprecated; use ExchangeMode.parse(spec) or the "
        "unified ExchangeConfig.parse(spec)")
    return ExchangeMode.parse(mode)


# ---------------------------------------------------------------------------
# straggler profiles (the fault/jitter injection layer)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _lognormal_barrier_mult(sigma: float, K: int,
                            samples: int = 8192) -> float:
    """E[max over K workers] of a mean-1 lognormal multiplier, by
    fixed-seed Monte Carlo (no closed form). Deterministic, cached."""
    z = np.random.default_rng(20260808).standard_normal((samples, K))
    mult = np.exp(sigma * z - 0.5 * sigma * sigma)
    return float(np.mean(np.max(mult, axis=1)))


@dataclass(frozen=True)
class StragglerProfile:
    """Per-worker compute-time multiplier distribution — the paper's
    straggling-executor regime (§4, Figs 4-5) as an explicit knob.

    Under a bulk-synchronous barrier every round waits for its slowest
    worker, so straggling changes *wall-clock only*: the drivers ignore
    the profile numerically (trajectories and wire traffic are
    straggler-invariant — regression-tested) while the trade-off
    layer's ``TimeModel`` charges compute as the max over workers.

      * ``none``              every worker runs at 1x.
      * ``det(slow=S)``       worker 0 is deterministically S× slower —
        the paper's "one bad executor" case; barrier factor exactly S.
      * ``lognormal(sigma=σ)``  mean-1 lognormal jitter on every worker
        (``exp(σz - σ²/2)``); barrier factor E[max of K] by fixed-seed
        Monte Carlo.
      * ``mix(p=P,slow=S)``   heavy-tail mix: each worker independently
        S× slow with probability P; barrier factor
        ``1 + (S-1)·(1-(1-P)^K)`` in closed form.

    ``multipliers`` samples one round's per-worker multipliers keyed
    off the same round-key ``split`` the drivers use for worker RNG.
    Canonical string spelling: ``"straggler:mix(p=0.1,slow=8)"`` etc.
    """
    kind: str = "none"
    slow: float = 4.0
    p: float = 0.1
    sigma: float = 0.5

    _PARAMS = {"none": (), "det": ("slow",), "lognormal": ("sigma",),
               "mix": ("p", "slow")}

    @classmethod
    def parse(cls, spec: "StragglerProfile | str") -> "StragglerProfile":
        if isinstance(spec, StragglerProfile):
            return spec
        body = str(spec)
        body = body[len("straggler:"):] if body.startswith("straggler:") \
            else body
        m = re.fullmatch(r"([a-z_]+)(?:\(([^()]*)\))?", body)
        if not m or m.group(1) not in STRAGGLER_KINDS:
            raise ValueError(f"unknown straggler profile {spec!r}; known "
                             f"kinds: {STRAGGLER_KINDS}, parameterized as "
                             f"'straggler:mix(p=0.1,slow=8)'")
        kind, params = m.group(1), m.group(2)
        allowed = cls._PARAMS[kind]
        kwargs = {}
        for item in (params.split(",") if params else ()):
            key, sep, val = item.partition("=")
            key = key.strip()
            if not sep or key not in allowed:
                raise ValueError(
                    f"straggler profile {spec!r}: '{kind}' takes "
                    f"{allowed or 'no'} parameters, got {item!r}")
            try:
                kwargs[key] = float(val)
            except ValueError:
                raise ValueError(f"straggler profile {spec!r}: parameter "
                                 f"{key}={val!r} is not a number") from None
        return cls(kind, **kwargs)

    def __post_init__(self):
        if self.kind not in STRAGGLER_KINDS:
            raise ValueError(f"unknown straggler profile kind "
                             f"{self.kind!r}; known: {STRAGGLER_KINDS}")
        if self.slow < 1.0:
            raise ValueError(f"straggler slow multiplier must be >= 1, "
                             f"got {self.slow}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"straggler probability p must be in [0, 1], "
                             f"got {self.p}")
        if self.sigma < 0.0:
            raise ValueError(f"straggler lognormal sigma must be >= 0, "
                             f"got {self.sigma}")

    @property
    def active(self) -> bool:
        return self.kind != "none"

    @property
    def spec(self) -> str:
        """Canonical string spelling (``parse(spec)`` round-trips; only
        the kind's own parameters are printed)."""
        fmt = {"slow": self.slow, "p": self.p, "sigma": self.sigma}
        args = ",".join(f"{k}={fmt[k]:g}" for k in self._PARAMS[self.kind])
        return f"straggler:{self.kind}" + (f"({args})" if args else "")

    def multipliers(self, key: jax.Array, K: int) -> jax.Array:
        """One round's per-worker compute-time multipliers, shape
        ``(K,)`` f32 — derived from the round key with the same
        ``split``-into-K-worker-keys plumbing the drivers use, so the
        jitter stream is reproducible and independent per worker."""
        if self.kind == "none":
            return jnp.ones((K,), jnp.float32)
        if self.kind == "det":
            return jnp.where(jnp.arange(K) == 0, self.slow,
                             1.0).astype(jnp.float32)
        keys = jax.random.split(jax.random.fold_in(key, 0x57A6), K)
        if self.kind == "lognormal":
            z = jax.vmap(lambda kk: jax.random.normal(kk, ()))(keys)
            return jnp.exp(self.sigma * z
                           - 0.5 * self.sigma**2).astype(jnp.float32)
        hit = jax.vmap(lambda kk: jax.random.bernoulli(kk, self.p))(keys)
        return jnp.where(hit, self.slow, 1.0).astype(jnp.float32)

    def barrier_mults(self, key: jax.Array, K: int,
                      rounds: int) -> jax.Array:
        """``(rounds,)`` sampled per-round barrier factors — the max
        over workers of :meth:`multipliers`, one round per key."""
        keys = jax.random.split(key, rounds)
        return jax.vmap(lambda kk: jnp.max(self.multipliers(kk, K)))(keys)

    def expected_barrier_mult(self, K: int) -> float:
        """E[max over K workers] of the multiplier — the factor a
        bulk-synchronous barrier stretches compute by (what
        ``TimeModel`` charges)."""
        if K < 1:
            raise ValueError(f"straggler barrier factor needs the worker "
                             f"count K >= 1, got {K}")
        if self.kind == "none":
            return 1.0
        if self.kind == "det":
            return float(self.slow)
        if self.kind == "mix":
            return 1.0 + (self.slow - 1.0) * (1.0 - (1.0 - self.p) ** K)
        return _lognormal_barrier_mult(self.sigma, K)


# ---------------------------------------------------------------------------
# elastic membership schedules
# ---------------------------------------------------------------------------
_DROP_RE = re.compile(r"drop:([0-9]+)@([0-9]+)(?:-([0-9]+))?")


@dataclass(frozen=True)
class MembershipSchedule:
    """Elastic worker membership: each event removes one worker for an
    inclusive window of 1-based rounds (``(worker, first, last)``;
    ``last=None`` means it never rejoins). Spelled ``"drop:1@5"`` /
    ``"drop:1@5-9"`` in exchange specs; multiple ``drop`` segments
    compose.

    Membership is evaluated *in-graph* from the traced round index, so
    one compiled round serves every round: a dropped worker still
    participates in the collectives but contributes an exact-zero
    update (zeroed BEFORE codec encode — zero is a guaranteed fixed
    point of every codec) and its persistent local state is frozen.
    The wire traffic therefore changes only via the live-worker count
    in the byte model, never via the HLO.
    """
    events: tuple = ()

    @staticmethod
    def parse_event(seg: str) -> tuple:
        m = _DROP_RE.fullmatch(seg)
        if not m:
            raise ValueError(f"malformed membership segment {seg!r}; the "
                             f"grammar is 'drop:<worker>@<round>' or "
                             f"'drop:<worker>@<first>-<last>'")
        w, d, r = int(m.group(1)), int(m.group(2)), m.group(3)
        return (w, d, None if r is None else int(r))

    @classmethod
    def parse(cls, spec: "MembershipSchedule | str") -> "MembershipSchedule":
        if isinstance(spec, MembershipSchedule):
            return spec
        segs = [s for s in str(spec).split("/") if s]
        return cls(tuple(cls.parse_event(s) for s in segs))

    def __post_init__(self):
        norm = []
        for ev in self.events:
            w, d, r = ev
            if w < 0 or d < 1 or (r is not None and r < d):
                raise ValueError(
                    f"membership event {ev!r}: need worker >= 0, first "
                    f"round >= 1 (rounds are 1-based) and last >= first")
            norm.append((int(w), int(d), None if r is None else int(r)))
        object.__setattr__(self, "events", tuple(norm))

    @property
    def empty(self) -> bool:
        return not self.events

    @property
    def spec(self) -> str:
        return "/".join(f"drop:{w}@{d}" if r is None else f"drop:{w}@{d}-{r}"
                        for (w, d, r) in self.events)

    def check_workers(self, K: int) -> None:
        for (w, _, _) in self.events:
            if w >= K:
                raise ValueError(f"membership schedule {self.spec!r} drops "
                                 f"worker {w} but the run has only K={K} "
                                 f"workers")

    def live_mask(self, t, K: int) -> jax.Array:
        """``(K,)`` f32 {0,1} mask of live workers at 1-based round
        ``t`` (``t`` may be traced — elementwise ops only, no
        collectives, so one compile serves every round)."""
        self.check_workers(K)
        mask = jnp.ones((K,), jnp.float32)
        for (w, d, r) in self.events:
            absent = (t >= d) if r is None else ((t >= d) & (t <= r))
            mask = mask.at[w].multiply(jnp.where(absent, 0.0, 1.0))
        return mask

    def live_count(self, t: int, K: int) -> int:
        """Concrete live-worker count at a concrete round ``t`` (the
        byte model's ``K_live``)."""
        self.check_workers(K)

        def absent(w):
            return any(w == ew and t >= d and (r is None or t <= r)
                       for (ew, d, r) in self.events)

        return sum(0 if absent(w) else 1 for w in range(K))


# ---------------------------------------------------------------------------
# the unified exchange configuration
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExchangeConfig:
    """Everything about how one run exchanges updates, in one frozen
    value: the comm scheme (transport x codec), the collective backend
    (which fabric moves the bytes — ``repro.comm.collectives``), the
    exchange mode (sync / bounded staleness), the straggler profile,
    and the elastic membership schedule.

    Round-trips to/from a ``"/"``-separated spec string whose segments
    may appear in any order::

        ExchangeConfig.parse("compressed:int4/stale:k=2")
        ExchangeConfig.parse("compressed:int4/ring/stale:k=2")
        ExchangeConfig.parse("persistent/straggler:mix(p=0.1,slow=8)")
        ExchangeConfig.parse("spark_faithful/drop:1@5-9/drop:3@7")

    Omitted segments take their defaults (``persistent``, ``sync``, no
    stragglers, full membership); ``str(cfg)`` prints the canonical
    spec with default segments elided. This is the ONE surface the
    drivers, the trainer configs, ``TimeModel`` and ``sweep_H`` accept;
    the scattered ``comm_scheme=`` / ``exchange_mode=`` string knobs
    are deprecated aliases that fold into it (one release of warning).
    """
    scheme: CommScheme = field(default_factory=lambda: CommScheme("persistent"))
    mode: ExchangeMode = field(default_factory=lambda: ExchangeMode("sync"))
    straggler: StragglerProfile = field(default_factory=StragglerProfile)
    membership: MembershipSchedule = field(default_factory=MembershipSchedule)
    backend: str = "xla"

    def __post_init__(self):
        # constructor convenience: each component may be given as its
        # own string spelling
        if isinstance(self.scheme, str):
            object.__setattr__(self, "scheme", CommScheme.parse(self.scheme))
        if isinstance(self.mode, str):
            object.__setattr__(self, "mode", ExchangeMode.parse(self.mode))
        if isinstance(self.straggler, str):
            object.__setattr__(self, "straggler",
                               StragglerProfile.parse(self.straggler))
        if isinstance(self.membership, (str, tuple)):
            object.__setattr__(
                self, "membership",
                MembershipSchedule.parse(self.membership)
                if isinstance(self.membership, str)
                else MembershipSchedule(self.membership))
        # the backend is stored by name (a backend object is folded to
        # its name so the config stays a frozen hashable value);
        # get_backend raises on unknown names
        object.__setattr__(self, "backend", get_backend(self.backend).name)

    @classmethod
    def parse(cls, spec: "ExchangeConfig | CommScheme | ExchangeMode | str",
              ) -> "ExchangeConfig":
        """Parse a spec string (or pass through / wrap an already-typed
        value). Segments are classified by their head token, so order
        never matters; duplicate scheme/mode/straggler segments are
        rejected loudly."""
        if isinstance(spec, ExchangeConfig):
            return spec
        if isinstance(spec, CommScheme):
            return cls(scheme=spec)
        if isinstance(spec, ExchangeMode):
            return cls(mode=spec)
        scheme = mode = straggler = backend = None
        events: list = []
        for seg in str(spec).split("/"):
            head = seg.partition(":")[0]
            if head in COLLECTIVE_BACKENDS:
                if seg != head:
                    raise ValueError(
                        f"exchange spec {spec!r}: collective-backend "
                        f"segment {seg!r} takes no parameters")
                if backend is not None:
                    raise ValueError(f"exchange spec {spec!r}: duplicate "
                                     f"collective-backend segment {seg!r}")
                backend = head
            elif head in COMM_TRANSPORTS:
                if scheme is not None:
                    raise ValueError(f"exchange spec {spec!r}: duplicate "
                                     f"comm-scheme segment {seg!r}")
                scheme = CommScheme.parse(seg)
            elif head in EXCHANGE_MODES:
                if mode is not None:
                    raise ValueError(f"exchange spec {spec!r}: duplicate "
                                     f"exchange-mode segment {seg!r}")
                mode = ExchangeMode.parse(seg)
            elif head == "straggler":
                if straggler is not None:
                    raise ValueError(f"exchange spec {spec!r}: duplicate "
                                     f"straggler segment {seg!r}")
                straggler = StragglerProfile.parse(seg)
            elif head == "drop":
                events.append(MembershipSchedule.parse_event(seg))
            else:
                raise ValueError(
                    f"unknown exchange spec segment {seg!r} in {spec!r}; "
                    f"the grammar is {EXCHANGE_GRAMMAR}")
        return cls(scheme=scheme if scheme is not None
                   else CommScheme("persistent"),
                   mode=mode if mode is not None else ExchangeMode("sync"),
                   straggler=straggler if straggler is not None
                   else StragglerProfile(),
                   membership=MembershipSchedule(tuple(events)),
                   backend=backend if backend is not None else "xla")

    @property
    def spec(self) -> str:
        """Canonical spec string: scheme first, then the backend when
        not the default ``xla``, then every other non-default segment;
        ``parse(spec)`` round-trips."""
        segs = [self.scheme.name]
        if self.backend != "xla":
            segs.append(self.backend)
        if self.mode.spec != "sync":
            segs.append(self.mode.spec)
        if self.straggler.active:
            segs.append(self.straggler.spec)
        if not self.membership.empty:
            segs.append(self.membership.spec)
        return "/".join(segs)

    def __str__(self) -> str:
        return self.spec


def resolve_exchange(exchange=None, *, comm_scheme=None, exchange_mode=None,
                     owner: str = "") -> ExchangeConfig:
    """Fold the unified ``exchange`` spec and the deprecated
    ``comm_scheme`` / ``exchange_mode`` knobs into ONE
    :class:`ExchangeConfig`.

    ``exchange`` given: it is authoritative; a legacy knob may ride
    along only if it agrees (configs re-pass their stored canonical
    values through ``dataclasses.replace``), otherwise ValueError.
    ``exchange`` absent: the legacy knobs build the config, with one
    :class:`~repro.utils.deprecation.ReproDeprecationWarning` when a
    non-default legacy value is used.
    """
    where = f"{owner}: " if owner else ""
    sch = None if comm_scheme is None else CommScheme.parse(comm_scheme)
    mod = None if exchange_mode is None else ExchangeMode.parse(exchange_mode)
    if exchange is not None:
        ex = ExchangeConfig.parse(exchange)
        conflicts = []
        if sch is not None and sch != ex.scheme:
            conflicts.append(f"comm_scheme={sch.name!r} vs exchange scheme "
                             f"{ex.scheme.name!r}")
        if mod is not None and mod != ex.mode:
            conflicts.append(f"exchange_mode={mod.spec!r} vs exchange mode "
                             f"{ex.mode.spec!r}")
        if conflicts:
            raise ValueError(
                f"{where}exchange={ex.spec!r} conflicts with deprecated "
                f"knob(s): {'; '.join(conflicts)} — drop the deprecated "
                f"spelling")
        return ex
    legacy = []
    if sch is not None and sch.name != "persistent":
        legacy.append(f"comm_scheme={sch.name!r}")
    if mod is not None and mod.spec != "sync":
        legacy.append(f"exchange_mode={mod.spec!r}")
    if legacy:
        warn_deprecated(
            f"{where}{' and '.join(legacy)} is deprecated; pass the "
            f"unified exchange spec instead (e.g. "
            f"exchange='compressed:int4/stale:k=2')", stacklevel=4)
    return ExchangeConfig(scheme=sch if sch is not None
                          else CommScheme("persistent"),
                          mode=mod if mod is not None
                          else ExchangeMode("sync"))


def init_exchange_state(mode: "ExchangeConfig | ExchangeMode | str", shared,
                        pending=None):
    """The drivers' ``shared`` slot for the given mode (an
    :class:`ExchangeConfig` is accepted and contributes its mode):
    ``sync`` passes the shared state through untouched; ``stale``
    pairs it with the carried pending-aggregate queue — a stacked
    ``(k, ...)`` leaf per shared leaf, zeros until real aggregates have
    flowed in (every algorithm here all-reduces an update shaped like
    its shared state, so stacked ``zeros_like(shared)`` is the default
    template). ``pending``, when given, must already be the stacked
    queue."""
    if isinstance(mode, ExchangeConfig):
        mode = mode.mode
    mode = ExchangeMode.parse(mode)
    if not mode.stale:
        return shared
    if pending is None:
        pending = jax.tree_util.tree_map(
            lambda s: jnp.zeros((mode.k,) + s.shape, s.dtype), shared)
    return (shared, pending)


def wrap_local_state(exchange, local, update_len: int, K: int):
    """The drivers' ``local`` slot for the given exchange: a stateless
    codec passes the per-worker local state through untouched; a
    *stateful* codec (the ``ef:`` error-feedback wrapper) pairs it with
    the stacked ``(K, update_len)`` per-worker codec-state carry — the
    residual every round's encode reads and rewrites. The mirror image
    of :func:`init_exchange_state` widening ``shared`` for ``stale``."""
    codec = ExchangeConfig.parse(exchange).scheme.codec
    if not getattr(codec, "stateful", False):
        return local
    return local, jnp.stack([codec.init_state(update_len)] * K)


def unwrap_local_state(exchange, local):
    """The bare per-worker local state, dropping the codec-state slot
    a stateful codec's run carries (the post-run counterpart of
    :func:`wrap_local_state`; identity for stateless codecs)."""
    codec = ExchangeConfig.parse(exchange).scheme.codec
    return local[0] if getattr(codec, "stateful", False) else local


def _masked_apply(algo: "RoundAlgorithm", shared, agg, idx):
    """Apply one aggregate under its own round index ``idx``, masked
    out entirely when ``idx < 1`` (the queue slot still holds only the
    zero init — an algorithm's ``apply_update`` need not be the
    identity on a zero update, e.g. SGD's proximal step still moves,
    so the no-round apply must be masked rather than trusted)."""
    applied = algo.apply_update(shared, agg, jnp.maximum(idx, 1))
    return jax.tree_util.tree_map(
        lambda a, s: jnp.where(idx < 1, s, a), applied, shared)


def _queue_head(queue, i: int):
    return jax.tree_util.tree_map(lambda q: q[i], queue)


def _queue_push(queue, total):
    """Shift the pending queue one slot and append this round's
    aggregate (slot ``j`` holds the aggregate from ``j`` shifts ago +
    1 ... i.e. after round ``t`` the queue holds rounds ``t-k+1..t``,
    oldest first)."""
    return jax.tree_util.tree_map(
        lambda q, tot: jnp.concatenate([q[1:], tot[None]], axis=0),
        queue, total)


def _delayed_apply(algo: "RoundAlgorithm", shared, queue, t, k: int):
    """Apply the oldest pending aggregate — round ``t-k``'s — under its
    own round index (masked out while ``t <= k``, when no real
    aggregate has reached the queue head yet)."""
    return _masked_apply(algo, shared, _queue_head(queue, 0), t - k)


def _absorb_for_metric(algo: "RoundAlgorithm", shared, queue, t, k: int):
    """The metric must be the objective of ONE real iterate: fold the
    remaining pending aggregates (rounds ``t-k+1 .. t-1``) into a
    metric-only copy of the shared state so it is absorbed through
    round ``t-1`` — exactly the iterate the round-``t-1`` local state
    pairs with. A no-op at ``k=1`` (bit-identity with the pre-bounded
    stale mode)."""
    for i in range(1, k):
        shared = _masked_apply(algo, shared, _queue_head(queue, i),
                               t - k + i)
    return shared


def _make_flush(algo: "RoundAlgorithm", mode: ExchangeMode) -> Callable:
    """``flush(shared_state, t) -> shared``: absorb every pending
    aggregate left over from the last executed round ``t`` (identity in
    sync mode). After round ``t`` the queue holds the aggregates of
    rounds ``t-k+1 .. t`` oldest-first; each is applied under its own
    round index, masked out for slots that never saw a real round
    (``t < k``). Without the flush a short stale run would silently
    drop its trailing updates — the off-by-one the single-round
    sync-vs-stale regression test pins."""
    if not mode.stale:
        return lambda shared, t: shared
    k = mode.k

    @jax.jit
    def flush(shared_state, t):
        shared, queue = shared_state
        for i in range(k):
            shared = _masked_apply(algo, shared, _queue_head(queue, i),
                                   t - (k - 1) + i)
        return shared

    return flush


def finish_run(round_fn: Callable, shared, last_t: int):
    """The one post-run epilogue every trainer loop shares: absorb the
    pending aggregate from the last executed round (``last_t`` is its
    1-based index; 0 means no round ran, so there is nothing pending
    and the bare shared state is unwrapped as-is)."""
    if last_t > 0:
        return round_fn.flush(shared, last_t)
    return shared[0] if round_fn.mode.stale else shared


# ---------------------------------------------------------------------------
# the algorithm protocol
# ---------------------------------------------------------------------------
class RoundAlgorithm(Protocol):
    """What one algorithm plugs into the generic round drivers.

    ``data``   tuple of ``(K, ...)`` stacked arrays, partitioned on the
               leading worker axis (column blocks for CoCoA/SCD, row
               blocks for SGD).
    ``local``  ``(K, L_local)`` per-worker persistent state (alpha
               blocks; empty ``(K, 0)`` when the algorithm has none).
    ``shared`` replicated state (the residual ``w`` / the model
               ``alpha``).
    """

    def local_step(self, data_k, local_k, shared, key, t):
        """One worker's round: returns ``(update, local_new)`` where
        ``update`` is the 1-D vector to be all-reduced."""
        ...

    def apply_update(self, shared, total_update, t):
        """New shared state from the all-reduced update (round ``t``)."""
        ...

    def local_metric(self, data_k, local_k, shared_new):
        """Per-worker scalar metric contribution (summed across workers)."""
        ...

    def finalize_metric(self, shared_new, metric_sum):
        """Round metric (e.g. the primal objective) from the summed
        per-worker contributions."""
        ...


# ---------------------------------------------------------------------------
# generic round drivers
# ---------------------------------------------------------------------------
def _builder_exchange(exchange, *, scheme, mode, owner: str,
                      K: int) -> ExchangeConfig:
    """Resolve a driver builder's exchange arguments: the unified
    ``exchange`` value (ExchangeConfig / CommScheme / spec string) plus
    the deprecated ``scheme=`` / ``mode=`` keyword aliases."""
    if exchange is None:
        if scheme is None:
            raise TypeError(f"{owner}() needs an exchange spec (an "
                            f"ExchangeConfig, a CommScheme, or a spec "
                            f"string like 'compressed:int4/stale:k=2')")
        warn_deprecated(f"{owner}(scheme=...) is deprecated; pass the "
                        f"scheme as the positional exchange spec",
                        stacklevel=4)
        exchange = scheme
    elif scheme is not None:
        raise TypeError(f"{owner}() got both an exchange spec and the "
                        f"deprecated scheme= alias")
    ex = ExchangeConfig.parse(exchange)
    if mode is not None:
        warn_deprecated(f"{owner}(mode=...) is deprecated; fold the mode "
                        f"into the exchange spec (e.g. "
                        f"'{ex.scheme.name}/stale:k=2')", stacklevel=4)
        parsed = ExchangeMode.parse(mode)
        if ex.mode.stale and parsed != ex.mode:
            raise ValueError(f"{owner}(): mode={parsed.spec!r} conflicts "
                            f"with exchange={ex.spec!r}")
        import dataclasses as _dc
        ex = _dc.replace(ex, mode=parsed)
    ex.membership.check_workers(K)
    return ex


def _freeze_dropped(local_new, local_old, mask):
    """Freeze dropped workers' persistent local state: a worker that is
    absent this round keeps its pre-round state verbatim (``mask`` is
    the (K,)-or-scalar live mask, broadcast over the state's trailing
    axis)."""
    m = mask[..., None] if jnp.ndim(local_new) > jnp.ndim(mask) else mask
    return jnp.where(m > 0, local_new, local_old)


def build_virtual_round(algo: RoundAlgorithm, exchange=None, data=None,
                        *, K: int, use_map: bool = False,
                        mode=None, scheme=None) -> Callable:
    """K *virtual* workers on however many real devices exist.

    ``exchange`` is an :class:`ExchangeConfig`, a :class:`CommScheme`,
    or a spec string (``"compressed:int4/stale:k=2/drop:1@5"``); the
    keyword ``scheme=`` / ``mode=`` spellings are deprecated aliases.

    Returns jitted ``round_fn(local, shared, key, t) -> (local_new,
    shared_new, metric)``. ``use_map`` runs workers with ``lax.map``
    instead of ``vmap`` (needed for interpret-mode Pallas solvers).
    Under a stale mode the ``shared`` slot is the ``(shared, queue)``
    pair from :func:`init_exchange_state`: workers compute against
    state absorbed through round ``t-1-k``, the oldest pending
    aggregate is applied alongside, and this round's aggregate joins
    the back of the queue. ``round_fn.flush`` absorbs the whole queue
    after the last round. Under a *stateful* codec (``ef:``) the
    ``local`` slot is the ``(local, codec_state)`` pair from
    :func:`wrap_local_state`: the residual advances at encode time
    every round, orthogonally to the stale queue (which only delays
    the aggregate's *apply*). Workers dropped by the membership
    schedule contribute exact-zero updates (zeroed before codec
    encode — residual included) and their local state AND residual are
    frozen; when the algorithm averages over workers
    (``live_reweight``) the aggregate is rescaled by ``K / K_live``.
    Straggler profiles never enter here — under a bulk-synchronous
    barrier they change wall-clock, not math.
    """
    ex = _builder_exchange(exchange, scheme=scheme, mode=mode,
                           owner="build_virtual_round", K=K)
    comm, xmode, membership = ex.scheme, ex.mode, ex.membership
    k = xmode.k
    stateful = bool(getattr(comm.codec, "stateful", False))
    reweight = (not membership.empty
                and getattr(algo, "live_reweight", False))

    @jax.jit
    def round_fn(local, shared, key, t=1):
        if stateful:
            local, cstate = local
        if xmode.stale:
            shared, queue = shared
        keys = jax.random.split(key, K)
        if use_map:
            upd, local_new = lax.map(
                lambda args: algo.local_step(args[0], args[1], shared,
                                             args[2], t),
                (data, local, keys))
        else:
            upd, local_new = jax.vmap(
                lambda d, l, k_: algo.local_step(d, l, shared, k_, t))(
                    data, local, keys)
        cstate_in = cstate if stateful else None
        if not membership.empty:
            mask = membership.live_mask(t, K)
            upd = upd * mask[:, None]
            local_new = _freeze_dropped(local_new, local, mask)
            if stateful:
                # a dropped worker contributes an exact-zero encode:
                # its residual is zeroed alongside the update (zero is
                # a codec fixed point) and frozen below, so it neither
                # leaks into the aggregate nor decays while absent
                cstate_in = cstate_in * mask[:, None]
        if stateful:
            total, cstate_new = comm.all_reduce_stacked(upd, cstate_in)
            if not membership.empty:
                cstate_new = _freeze_dropped(cstate_new, cstate, mask)
        else:
            total = comm.all_reduce_stacked(upd)
        if reweight:
            total = total * (K / jnp.maximum(jnp.sum(mask), 1.0))
        if xmode.stale:
            shared_new = _delayed_apply(algo, shared, queue, t, k)
            shared_out = (shared_new, _queue_push(queue, total))
            # the metric must be the objective of ONE iterate: pair the
            # shared state absorbed through round t-1 (the metric-only
            # absorb of the still-pending aggregates) with the ROUND-t-1
            # local state (for CoCoA, w = A@alpha - b holds exactly for
            # that pair). Mixing in the round-t local state produces a
            # value that is no iterate's objective and can dip below
            # p_star. Under stale the recorded metric therefore lags
            # one round — the honest cost of the delayed apply.
            metric_shared = _absorb_for_metric(algo, shared_new, queue, t, k)
            metric_local = local
        else:
            shared_new = algo.apply_update(shared, total, t)
            shared_out = shared_new
            metric_shared = shared_new
            metric_local = local_new
        metric_sum = jnp.sum(jax.vmap(
            lambda d, l: algo.local_metric(d, l, metric_shared))(
                data, metric_local))
        local_out = (local_new, cstate_new) if stateful else local_new
        return local_out, shared_out, algo.finalize_metric(metric_shared,
                                                           metric_sum)

    round_fn.exchange = ex
    round_fn.mode = xmode
    round_fn.stateful_codec = stateful
    round_fn.flush = _make_flush(algo, xmode)
    return round_fn


def build_sharded_round(algo: RoundAlgorithm, exchange=None, data=None,
                        mesh: Mesh = None, *, donate: bool = True,
                        mode=None, scheme=None) -> Callable:
    """Real distribution via ``shard_map`` over the mesh's single axis.

    ``exchange`` is an :class:`ExchangeConfig`, a :class:`CommScheme`,
    or a spec string; the keyword ``scheme=`` / ``mode=`` spellings are
    deprecated aliases. Returns jitted ``round_fn(local, shared, key,
    t) -> (local_new, shared_new, metric)`` with ``local``/``shared``
    donated. The mesh axis size must equal the worker count K (the
    leading dim of every ``data`` leaf and of ``local``). Under a stale
    mode the ``shared`` slot is the ``(shared, queue)`` pair — same
    delayed apply, same collectives (the wire traffic is
    mode-independent, which the drivers benchmark asserts against the
    HLO), same per-worker RNG as the virtual driver. Membership masks
    are evaluated redundantly per shard from the replicated round
    index — elementwise ops only, so the HLO collectives are
    membership-invariant too.
    """
    K = mesh.devices.size
    ex = _builder_exchange(exchange, scheme=scheme, mode=mode,
                           owner="build_sharded_round", K=K)
    comm, xmode, membership = ex.scheme, ex.mode, ex.membership
    k = xmode.k
    stateful = bool(getattr(comm.codec, "stateful", False))
    reweight = (not membership.empty
                and getattr(algo, "live_reweight", False))
    axis = mesh.axis_names[0]
    for leaf in jax.tree_util.tree_leaves(data):
        assert leaf.shape[0] == K, (leaf.shape, K)

    def shard_fn(data_sh, local_sh, keys_sh, shared, t):
        if stateful:
            local_sh, cstate_sh = local_sh
            cstate_k = cstate_sh[0]
        data_k = jax.tree_util.tree_map(lambda x: x[0], data_sh)
        local_k = local_sh[0]
        key_k = jax.random.wrap_key_data(keys_sh[0])
        if xmode.stale:
            shared, queue = shared
        upd, local_new = algo.local_step(data_k, local_k, shared, key_k, t)
        cstate_in = cstate_k if stateful else None
        if not membership.empty:
            mask = membership.live_mask(t, K)
            mask_k = mask[lax.axis_index(axis)]
            upd = upd * mask_k
            local_new = _freeze_dropped(local_new, local_k, mask_k)
            if stateful:
                # same contract as the virtual driver: a dropped
                # worker's residual is zeroed before encode and frozen
                # after — exact-zero wire contribution, no decay
                cstate_in = cstate_in * mask_k
        if stateful:
            total, cstate_new = comm.all_reduce(upd, axis,
                                                backend=ex.backend,
                                                state=cstate_in)
            if not membership.empty:
                cstate_new = _freeze_dropped(cstate_new, cstate_k, mask_k)
        else:
            total = comm.all_reduce(upd, axis, backend=ex.backend)
        if reweight:
            total = total * (K / jnp.maximum(jnp.sum(mask), 1.0))
        if xmode.stale:
            shared_new = _delayed_apply(algo, shared, queue, t, k)
            shared_out = (shared_new, _queue_push(queue, total))
            metric_shared = _absorb_for_metric(algo, shared_new, queue, t, k)
        else:
            shared_new = algo.apply_update(shared, total, t)
            shared_out = shared_new
            metric_shared = shared_new
        local_new = comm.roundtrip_local_state(local_new, axis,
                                               backend=ex.backend)
        # stale pairs the lagged shared state with the round-t-1 local
        # state so the metric is a real iterate's objective (see the
        # virtual driver) — and matches it round for round
        metric_local = local_k if xmode.stale else local_new
        metric_sum = lax.psum(algo.local_metric(data_k, metric_local,
                                                metric_shared), axis)
        metric = algo.finalize_metric(metric_shared, metric_sum)
        local_out = ((local_new[None], cstate_new[None]) if stateful
                     else local_new[None])
        return local_out, shared_out, metric

    data_specs = jax.tree_util.tree_map(lambda _: P(axis), data)
    sharded = compat.shard_map(
        shard_fn, mesh,
        in_specs=(data_specs, P(axis), P(axis), P(None), P()),
        out_specs=(P(axis), P(None), P()))

    @functools.partial(jax.jit, donate_argnums=(1, 2) if donate else ())
    def jitted(keys, local, shared, t):
        return sharded(data, local, keys, shared, t)

    @functools.partial(jax.jit, donate_argnums=(2, 3) if donate else ())
    def jitted_data(data_arg, keys, local, shared, t):
        # data as an explicit argument instead of a closure constant:
        # multi-process runs (launch.dist) place the data as GLOBAL
        # arrays, and jit forbids closing over arrays that span
        # non-addressable devices — traced only if actually used
        return sharded(data_arg, local, keys, shared, t)

    def split_keys(key):
        # same per-worker key derivation as the virtual driver, so the
        # two paths follow the same trajectory; computed OUTSIDE the
        # jitted round so XLA does not partition the threefry split into
        # spurious u32 collectives (which would pollute the HLO traffic
        # the byte accounting is checked against)
        return jax.random.key_data(jax.random.split(key, K))

    def round_fn(local, shared, key, t=1):
        return jitted(split_keys(key), local, shared, t)

    # the jitted inner + key derivation, exposed for AOT lowering (HLO
    # collective-traffic inspection in benches/tests) and state placement
    round_fn.jitted = jitted
    round_fn.jitted_data = jitted_data
    round_fn.split_keys = split_keys
    round_fn.mesh = mesh
    round_fn.exchange = ex
    round_fn.mode = xmode
    round_fn.stateful_codec = stateful
    round_fn.flush = _make_flush(algo, xmode)
    return round_fn


def place_state(mesh: Mesh, local, shared, axis: str | None = None):
    """Device-put ``(local, shared)`` for the sharded driver: ``local``
    partitioned over the worker axis, ``shared`` replicated (``shared``
    may be the stale mode's ``(shared, pending)`` pair — every leaf is
    replicated)."""
    axis = axis or mesh.axis_names[0]
    local = jax.device_put(local, NamedSharding(mesh, P(axis)))
    shared = jax.device_put(shared, NamedSharding(mesh, P(None)))
    return local, shared
