"""Column partitioning of the data matrix across workers.

Two strategies, mirroring the paper:
  * ``block``     — contiguous equal-width column blocks (what Spark's
                    default partitioning gives after a columnar load).
  * ``balanced``  — the paper's MPI load-balancing partitioner: greedy
                    bin-packing so that sum_i nnz(c_i) is roughly equal
                    per partition.

Both return a permutation + per-worker index sets, and a packer that
produces the stacked dense (K, m, n_k) tensor used by the virtual-worker
and shard_map drivers (columns zero-padded to a common width).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Partition:
    K: int
    # index sets: list of np arrays of column ids, one per worker
    owned: tuple
    n_padded: int  # common padded width

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(p) for p in self.owned])


def block_partition(n: int, K: int) -> Partition:
    ids = np.arange(n)
    chunks = np.array_split(ids, K)
    n_pad = max(len(c) for c in chunks)
    return Partition(K=K, owned=tuple(chunks), n_padded=n_pad)


def balanced_partition(nnz_per_col: np.ndarray, K: int) -> Partition:
    """Greedy largest-first bin packing on per-column nonzero counts."""
    n = len(nnz_per_col)
    order = np.argsort(-nnz_per_col, kind="stable")
    loads = np.zeros(K)
    buckets: list[list[int]] = [[] for _ in range(K)]
    for j in order:
        k = int(np.argmin(loads))
        buckets[k].append(int(j))
        loads[k] += nnz_per_col[j]
    owned = tuple(np.array(sorted(bkt), dtype=np.int64) for bkt in buckets)
    n_pad = max(len(b) for b in buckets)
    return Partition(K=K, owned=owned, n_padded=n_pad)


def partition_imbalance(part: Partition, nnz_per_col: np.ndarray) -> float:
    """max/mean per-worker nnz load — 1.0 is perfectly balanced."""
    loads = np.array([nnz_per_col[p].sum() for p in part.owned], dtype=np.float64)
    return float(loads.max() / max(loads.mean(), 1e-12))


def pack_columns(A: np.ndarray, part: Partition) -> tuple[np.ndarray, np.ndarray]:
    """Stack worker column-blocks into (K, m, n_pad) with zero padding.

    Returns (A_stacked, mask) where mask is (K, n_pad) with 1.0 for real
    columns. Zero-padded columns have zero norm; the SCD solvers guard
    against picking them (update is exactly 0 for an all-zero column, and
    the sampling distribution masks them out).
    """
    m, _ = A.shape
    K, n_pad = part.K, part.n_padded
    out = np.zeros((K, m, n_pad), dtype=A.dtype)
    mask = np.zeros((K, n_pad), dtype=A.dtype)
    for k, ids in enumerate(part.owned):
        out[k, :, : len(ids)] = A[:, ids]
        mask[k, : len(ids)] = 1.0
    return out, mask


def unpack_alpha(alpha_stacked: np.ndarray, part: Partition, n: int) -> np.ndarray:
    """Scatter stacked per-worker alpha blocks back to global coordinates."""
    alpha = np.zeros(n, dtype=alpha_stacked.dtype)
    for k, ids in enumerate(part.owned):
        alpha[ids] = alpha_stacked[k, : len(ids)]
    return alpha
