"""CoCoA: communication-efficient distributed primal-dual GLM training.

Two execution drivers over identical math, both built on the unified
distributed-driver layer (``repro.core.distributed``):

  * ``CoCoATrainer.run()`` — K *virtual* workers on however many real
    devices exist (vmap over the worker axis). Used for convergence
    studies and the paper-figure benchmarks on CPU.
  * ``CoCoATrainer.run_sharded()`` — real distribution via ``shard_map``
    over a 1-D ``workers`` mesh axis with an explicit all-reduce of the
    m-dimensional update Delta v (the paper's AllReduce pattern, Fig 1).

Communication schemes (the paper's §5.3 plus one beyond-paper variant;
see ``distributed.CommScheme`` for the mechanics and byte accounting):

  * ``persistent``      — alpha_[k] lives on its worker across rounds
    (the paper's "persistent local memory" / (B)*, (D)* optimization;
    on TPU this is simply donated device-resident state).
  * ``spark_faithful``  — everything is shipped through the master every
    round: Delta v is collected (all-gather) and summed locally, and
    alpha is all-gathered with each worker re-slicing its own block.
    Mathematically the identity, but the extra collective traffic is
    real and visible in the HLO (and is charged by the overhead model).
  * ``compressed``      — int8-quantized Delta v exchange (4x less
    traffic than f32) through the one shared quantizer in
    ``distributed.quantize_update``.
  * ``reduce_scatter``  — the Delta v exchange as an explicit
    ``psum_scatter`` + ``all_gather`` ring pair: 2*(K-1)/K of the
    vector per worker each way, the cheapest exact f32 exchange.

Orthogonal to the scheme, ``exchange_mode`` picks the staleness regime
(``distributed.ExchangeMode``): ``sync`` applies the round's aggregate
immediately; ``stale`` applies it one round late (workers compute
against the unapplied residual — the paper's §4-§5 Spark
scheduling-delay regime as an explicit knob), with the final pending
Delta v flushed after the last round so nothing is dropped.

Mini-batch SCD (the paper's §2.1 baseline) runs the same drivers with
the fixed-residual solver — see ``repro.core.baselines.MinibatchSCD``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import distributed as dist
from repro.core import partition as part_mod
from repro.core import solvers
from repro.core.glm import GLMProblem, optimal_objective, primal_objective, suboptimality
from repro.utils import compat


@dataclass(frozen=True)
class CoCoAConfig:
    K: int = 8                       # number of workers
    H: int = 256                     # local SCD steps per round
    lam: float = 1.0
    eta: float = 1.0                 # 1.0 = ridge
    sigma: float | None = None       # subproblem safety; default K ("adding")
    solver: str = "scd_ref"          # scd_ref | scd_kernel | scd_fixed
    # the unified exchange surface: an ExchangeConfig or a spec string
    # like "compressed:int4/stale:k=2/drop:1@5" (see
    # distributed.ExchangeConfig for the grammar); None means the
    # default persistent/sync exchange unless the deprecated knobs below
    # say otherwise
    exchange: "dist.ExchangeConfig | str | None" = None
    comm_scheme: str | None = None   # DEPRECATED alias -> exchange
    exchange_mode: str | None = None  # DEPRECATED alias -> exchange
    partitioner: str = "balanced"    # balanced | block
    seed: int = 0

    def __post_init__(self):
        # fold the deprecated comm_scheme/exchange_mode strings and the
        # unified spec into ONE validated ExchangeConfig (a typo'd
        # scheme or mode must fail loudly, not silently fall through to
        # persistent/synchronous behavior), then store the canonical
        # values back so dataclasses.replace(cfg, ...) round-trips
        # silently and reads of the legacy fields stay truthful
        ex = dist.resolve_exchange(self.exchange,
                                   comm_scheme=self.comm_scheme,
                                   exchange_mode=self.exchange_mode,
                                   owner=type(self).__name__)
        object.__setattr__(self, "exchange", ex)
        object.__setattr__(self, "comm_scheme", ex.scheme.name)
        object.__setattr__(self, "exchange_mode", ex.mode.spec)
        if self.partitioner not in ("balanced", "block"):
            raise ValueError(f"unknown partitioner {self.partitioner!r}; "
                             f"known: ('balanced', 'block')")

    @property
    def sigma_val(self) -> float:
        return float(self.K if self.sigma is None else self.sigma)


@dataclass
class History:
    rounds: list = field(default_factory=list)
    primal: list = field(default_factory=list)
    subopt: list = field(default_factory=list)
    p_star: float = float("nan")
    p_zero: float = float("nan")

    def rounds_to(self, eps: float) -> int | None:
        for r, s in zip(self.rounds, self.subopt):
            if s <= eps:
                return r
        return None


def _get_solver(name: str) -> Callable:
    if name == "scd_ref":
        return solvers.scd_steps
    if name == "scd_fixed":
        return solvers.scd_steps_fixed_point
    if name == "scd_kernel":
        from repro.kernels import ops as kops
        return kops.scd_steps_kernel
    raise ValueError(f"unknown local solver {name!r}")


class _CoCoARound:
    """CoCoA's plug into the generic round drivers: the local SCD solve,
    the residual update ``w += sum_k Delta v_k``, and the primal metric
    evaluated without gathering alpha (``loss(w) + psum(reg_k)``).

    Mini-batch SCD rides the same adapter: with ``solver="scd_fixed"``
    the aggregation is damped by 1/sigma (paper §2.1) — in ONE place, so
    the virtual and sharded paths cannot disagree about it.
    """

    def __init__(self, cfg: CoCoAConfig, problem: GLMProblem,
                 solver: Callable):
        self.cfg, self.problem, self.solver = cfg, problem, solver

    def local_step(self, data_k, alpha_k, w, key, t):
        cfg = self.cfg
        A_k, col_sq_k, mask_k = data_k
        logits = jnp.where(mask_k > 0, 0.0, -jnp.inf)
        idx = jax.random.categorical(key, logits,
                                     shape=(cfg.H,)).astype(jnp.int32)
        dv, alpha_new = self.solver(A_k, col_sq_k, alpha_k, w, idx,
                                    sigma=cfg.sigma_val, lam=cfg.lam,
                                    eta=cfg.eta)
        if cfg.solver == "scd_fixed":
            # damped mini-batch aggregation: scale BOTH the local alpha
            # move and Delta v by 1/sigma so the shared-residual
            # invariant w = A alpha - b survives the round (damping only
            # dv silently de-synced alpha from w).
            alpha_new = alpha_k + (alpha_new - alpha_k) / cfg.sigma_val
            dv = dv / cfg.sigma_val
        return dv, alpha_new

    def apply_update(self, w, total_dv, t):
        return w + total_dv

    def local_metric(self, data_k, alpha_k, w_new):
        _, _, mask_k = data_k
        return self.problem.regularizer(alpha_k * mask_k)

    def finalize_metric(self, w_new, reg_sum):
        return self.problem.loss(w_new) + reg_sum


class CoCoATrainer:
    """Owns the partitioned data and the jitted round functions."""

    def __init__(self, cfg: CoCoAConfig, A: np.ndarray, b: np.ndarray):
        self.cfg = cfg
        self.problem = GLMProblem(lam=cfg.lam, eta=cfg.eta)
        self.exchange = cfg.exchange
        self.scheme = self.exchange.scheme
        self.mode = self.exchange.mode
        self.A_np, self.b_np = np.asarray(A, np.float32), np.asarray(b, np.float32)
        m, n = A.shape
        self.m, self.n = m, n
        nnz = (np.abs(self.A_np) > 0).sum(axis=0)
        if cfg.partitioner == "balanced":
            self.part = part_mod.balanced_partition(nnz, cfg.K)
        else:
            self.part = part_mod.block_partition(n, cfg.K)
        A_st, mask = part_mod.pack_columns(self.A_np, self.part)
        self.A_st = jnp.asarray(A_st)                       # (K, m, n_pad)
        self.mask = jnp.asarray(mask)                       # (K, n_pad)
        self.col_sq = jnp.sum(self.A_st ** 2, axis=1)       # (K, n_pad)
        self.b = jnp.asarray(self.b_np)
        self._solver = _get_solver(cfg.solver)
        self._algo = _CoCoARound(cfg, self.problem, self._solver)
        self._data = (self.A_st, self.col_sq, self.mask)
        self._round_fn = dist.build_virtual_round(
            self._algo, self.exchange, self._data, K=cfg.K,
            use_map=(cfg.solver == "scd_kernel"))  # pallas interpret: no vmap
        self._p_star_cache: float | None = None

    @property
    def p_star(self) -> float:
        if self._p_star_cache is None:
            self._p_star_cache = optimal_objective(self.problem, self.A_np, self.b_np)
        return self._p_star_cache

    @property
    def p_zero(self) -> float:
        return float(self.problem.loss(-self.b))

    def init_state(self):
        alpha = jnp.zeros((self.cfg.K, self.part.n_padded), jnp.float32)
        w = -self.b  # w = A @ 0 - b
        # stale mode widens the shared slot to (w, pending Delta v
        # queue); a stateful (ef:) codec widens the local slot to
        # (alpha, per-worker residual over the m-length Delta v)
        alpha = dist.wrap_local_state(self.exchange, alpha, self.m,
                                      self.cfg.K)
        return alpha, dist.init_exchange_state(self.exchange, w)

    def with_H(self, H: int) -> "CoCoATrainer":
        """A fresh trainer on the same problem with the H knob moved —
        the one sanctioned way to perturb a config (``dataclasses.replace``
        survives the dataclass gaining derived/non-init fields, a
        ``**cfg.__dict__`` splat does not)."""
        return type(self)(dataclasses.replace(self.cfg, H=int(H)),
                          self.A_np, self.b_np)

    def comm_bytes_per_round(self, t: int | None = None) -> int:
        """Modelled bytes through the master per round under the
        configured scheme — sized to the tensors the sharded collectives
        actually move (int8 Delta v + f32 scale for ``compressed``, f32
        otherwise; the alpha round-trip counts the padded blocks).
        ``t`` asks for a specific 1-based round under the elastic
        membership schedule: dropped workers ship nothing, so traffic
        scales with the live-worker count (``None`` = all K live, the
        schedule-free steady state)."""
        K_live = (None if t is None
                  else self.exchange.membership.live_count(t, self.cfg.K))
        return self.scheme.bytes_per_round(
            self.m, self.cfg.K,
            local_state_len=self.cfg.K * self.part.n_padded,
            K_live=K_live, backend=self.exchange.backend)

    # ------------------------------------------------------------------
    # the one record loop both drivers share
    # ------------------------------------------------------------------
    def _record_loop(self, round_fn, alpha, w, rounds: int,
                     record_every: int,
                     target_eps: float | None) -> History:
        key = jax.random.key(self.cfg.seed)
        hist = History(p_star=self.p_star, p_zero=self.p_zero)
        last_t = 0
        for t in range(rounds):
            last_t = t + 1
            key, sub = jax.random.split(key)
            alpha, w, primal = round_fn(alpha, w, sub, t + 1)
            if (t + 1) % record_every == 0 or t == rounds - 1:
                p = float(primal)
                s = suboptimality(p, hist.p_star, hist.p_zero)
                hist.rounds.append(t + 1)
                hist.primal.append(p)
                hist.subopt.append(s)
                if target_eps is not None and s <= target_eps:
                    break
        # stale runs carry one unapplied aggregate; absorb it so the
        # final iterate reflects every round that was computed, and
        # drop the codec-state slot a stateful (ef:) codec carried
        w = dist.finish_run(round_fn, w, last_t)
        alpha = dist.unwrap_local_state(self.exchange, alpha)
        self.w_final = np.asarray(w)
        self.alpha_final = part_mod.unpack_alpha(np.asarray(alpha),
                                                 self.part, self.n)
        return hist

    # ------------------------------------------------------------------
    # virtual-worker (vmap) driver
    # ------------------------------------------------------------------
    def run(self, rounds: int, record_every: int = 1,
            target_eps: float | None = None) -> History:
        alpha, w = self.init_state()
        return self._record_loop(self._round_fn, alpha, w, rounds,
                                 record_every, target_eps)

    # ------------------------------------------------------------------
    # shard_map driver (real distribution over devices)
    # ------------------------------------------------------------------
    def build_sharded_round(self, mesh: Mesh):
        """Distributed round via the generic shard_map driver; K must
        equal the mesh axis size. Returns jitted
        ``round_fn(alpha_st, w, key, t)``."""
        assert mesh.devices.size == self.cfg.K, (mesh.devices.size, self.cfg.K)
        return dist.build_sharded_round(self._algo, self.exchange,
                                        self._data, mesh)

    def run_sharded(self, rounds: int, mesh: Mesh | None = None,
                    record_every: int = 1,
                    target_eps: float | None = None) -> History:
        if mesh is None:
            mesh = compat.make_mesh((self.cfg.K,), ("workers",))
        round_fn = self.build_sharded_round(mesh)
        alpha, w = dist.place_state(mesh, *self.init_state())
        return self._record_loop(round_fn, alpha, w, rounds, record_every,
                                 target_eps)

    # ------------------------------------------------------------------
    def objective_of(self, alpha_global: np.ndarray) -> float:
        return float(primal_objective(self.problem, jnp.asarray(self.A_np),
                                      self.b, jnp.asarray(alpha_global)))
