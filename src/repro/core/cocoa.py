"""CoCoA: communication-efficient distributed primal-dual GLM training.

Two execution drivers over identical math:

  * ``CoCoATrainer.run()`` — K *virtual* workers on however many real
    devices exist (vmap over the worker axis). Used for convergence
    studies and the paper-figure benchmarks on CPU.
  * ``CoCoATrainer.run_sharded()`` — real distribution via ``shard_map``
    over a 1-D ``workers`` mesh axis with an explicit ``psum`` of the
    m-dimensional update Delta v (the paper's AllReduce pattern, Fig 1).

Communication schemes (the paper's §5.3):

  * ``persistent``      — alpha_[k] lives on its worker across rounds
    (the paper's "persistent local memory" / (B)*, (D)* optimization;
    on TPU this is simply donated device-resident state).
  * ``spark_faithful``  — alpha is shipped through the master every
    round, modelled as an all-gather of the full alpha followed by each
    worker re-slicing its own block. Mathematically the identity, but
    the extra collective traffic is real and visible in the HLO (and is
    charged by the overhead model in the virtual driver).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import partition as part_mod
from repro.core import solvers
from repro.core.glm import GLMProblem, optimal_objective, primal_objective, suboptimality
from repro.utils import compat


@dataclass(frozen=True)
class CoCoAConfig:
    K: int = 8                       # number of workers
    H: int = 256                     # local SCD steps per round
    lam: float = 1.0
    eta: float = 1.0                 # 1.0 = ridge
    sigma: float | None = None       # subproblem safety; default K ("adding")
    solver: str = "scd_ref"          # scd_ref | scd_kernel | scd_fixed
    comm_scheme: str = "persistent"  # persistent | spark_faithful
    partitioner: str = "balanced"    # balanced | block
    seed: int = 0

    @property
    def sigma_val(self) -> float:
        return float(self.K if self.sigma is None else self.sigma)


@dataclass
class History:
    rounds: list = field(default_factory=list)
    primal: list = field(default_factory=list)
    subopt: list = field(default_factory=list)
    p_star: float = float("nan")
    p_zero: float = float("nan")

    def rounds_to(self, eps: float) -> int | None:
        for r, s in zip(self.rounds, self.subopt):
            if s <= eps:
                return r
        return None


def _get_solver(name: str) -> Callable:
    if name == "scd_ref":
        return solvers.scd_steps
    if name == "scd_fixed":
        return solvers.scd_steps_fixed_point
    if name == "scd_kernel":
        from repro.kernels import ops as kops
        return kops.scd_steps_kernel
    raise ValueError(f"unknown local solver {name!r}")


class CoCoATrainer:
    """Owns the partitioned data and the jitted round functions."""

    def __init__(self, cfg: CoCoAConfig, A: np.ndarray, b: np.ndarray):
        self.cfg = cfg
        self.problem = GLMProblem(lam=cfg.lam, eta=cfg.eta)
        self.A_np, self.b_np = np.asarray(A, np.float32), np.asarray(b, np.float32)
        m, n = A.shape
        self.m, self.n = m, n
        nnz = (np.abs(self.A_np) > 0).sum(axis=0)
        if cfg.partitioner == "balanced":
            self.part = part_mod.balanced_partition(nnz, cfg.K)
        else:
            self.part = part_mod.block_partition(n, cfg.K)
        A_st, mask = part_mod.pack_columns(self.A_np, self.part)
        self.A_st = jnp.asarray(A_st)                       # (K, m, n_pad)
        self.mask = jnp.asarray(mask)                       # (K, n_pad)
        self.col_sq = jnp.sum(self.A_st ** 2, axis=1)       # (K, n_pad)
        self.b = jnp.asarray(self.b_np)
        self._solver = _get_solver(cfg.solver)
        self._round_fn = self._build_round()
        self._p_star_cache: float | None = None

    # ------------------------------------------------------------------
    # virtual-worker (vmap) driver
    # ------------------------------------------------------------------
    def _build_round(self):
        cfg, problem = self.cfg, self.problem
        sigma = cfg.sigma_val
        solver = self._solver
        use_map = cfg.solver == "scd_kernel"  # pallas interpret: avoid vmap

        def worker(A_k, col_sq_k, mask_k, alpha_k, key, w):
            logits = jnp.where(mask_k > 0, 0.0, -jnp.inf)
            idx = jax.random.categorical(key, logits, shape=(cfg.H,)).astype(jnp.int32)
            if cfg.solver == "scd_fixed":
                dv, alpha_new = solver(A_k, col_sq_k, alpha_k, w, idx,
                                       sigma=sigma, lam=cfg.lam, eta=cfg.eta)
                dv = dv / sigma  # damped aggregation for the mini-batch baseline
            else:
                dv, alpha_new = solver(A_k, col_sq_k, alpha_k, w, idx,
                                       sigma=sigma, lam=cfg.lam, eta=cfg.eta)
            return dv, alpha_new

        @jax.jit
        def round_fn(alpha_st, w, key):
            keys = jax.random.split(key, cfg.K)
            if use_map:
                dv, alpha_new = lax.map(
                    lambda args: worker(*args, w),
                    (self.A_st, self.col_sq, self.mask, alpha_st, keys))
            else:
                dv, alpha_new = jax.vmap(worker, in_axes=(0, 0, 0, 0, 0, None))(
                    self.A_st, self.col_sq, self.mask, alpha_st, keys, w)
            if cfg.comm_scheme == "compressed":
                # int8 quantization of each worker's update (see shard_fn)
                scale = jnp.max(jnp.abs(dv), axis=1) / 127.0 + 1e-30
                q = jnp.clip(jnp.round(dv / scale[:, None]), -127, 127)
                dv = jnp.round(q) * scale[:, None]
            w_new = w + jnp.sum(dv, axis=0)
            reg = problem.regularizer(alpha_new * self.mask)
            primal = problem.loss(w_new) + reg
            return alpha_new, w_new, primal

        return round_fn

    @property
    def p_star(self) -> float:
        if self._p_star_cache is None:
            self._p_star_cache = optimal_objective(self.problem, self.A_np, self.b_np)
        return self._p_star_cache

    @property
    def p_zero(self) -> float:
        return float(self.problem.loss(-self.b))

    def init_state(self):
        alpha = jnp.zeros((self.cfg.K, self.part.n_padded), jnp.float32)
        w = -self.b  # w = A @ 0 - b
        return alpha, w

    def run(self, rounds: int, record_every: int = 1,
            target_eps: float | None = None) -> History:
        alpha, w = self.init_state()
        key = jax.random.key(self.cfg.seed)
        hist = History(p_star=self.p_star, p_zero=self.p_zero)
        for t in range(rounds):
            key, sub = jax.random.split(key)
            alpha, w, primal = self._round_fn(alpha, w, sub)
            if (t + 1) % record_every == 0 or t == rounds - 1:
                p = float(primal)
                s = suboptimality(p, hist.p_star, hist.p_zero)
                hist.rounds.append(t + 1)
                hist.primal.append(p)
                hist.subopt.append(s)
                if target_eps is not None and s <= target_eps:
                    break
        self.alpha_final = part_mod.unpack_alpha(np.asarray(alpha), self.part, self.n)
        return hist

    # ------------------------------------------------------------------
    # shard_map driver (real distribution over devices)
    # ------------------------------------------------------------------
    def build_sharded_round(self, mesh: Mesh):
        """Distributed round via shard_map; K must equal mesh axis size."""
        cfg, problem = self.cfg, self.problem
        sigma = cfg.sigma_val
        solver = self._solver
        axis = mesh.axis_names[0]
        assert mesh.devices.size == cfg.K, (mesh.devices.size, cfg.K)

        def shard_fn(A_k, col_sq_k, mask_k, alpha_k, key_k, w):
            A_k, col_sq_k, mask_k, alpha_k = (x[0] for x in
                                              (A_k, col_sq_k, mask_k, alpha_k))
            key = jax.random.key_data(jax.random.fold_in(
                jax.random.wrap_key_data(key_k[0]), lax.axis_index(axis)))
            logits = jnp.where(mask_k > 0, 0.0, -jnp.inf)
            idx = jax.random.categorical(jax.random.wrap_key_data(key), logits,
                                         shape=(cfg.H,)).astype(jnp.int32)
            dv, alpha_new = solver(A_k, col_sq_k, alpha_k, w, idx,
                                   sigma=sigma, lam=cfg.lam, eta=cfg.eta)
            if cfg.comm_scheme == "compressed":
                # beyond-paper: int8-quantized Delta v exchange (4x less
                # traffic than f32). Per-worker absmax scale travels as a
                # tiny f32 alongside; dequant + sum happens locally.
                scale = jnp.max(jnp.abs(dv)) / 127.0 + 1e-30
                q = jnp.clip(jnp.round(dv / scale), -127, 127).astype(jnp.int8)
                qs = lax.all_gather(q, axis)           # (K, m) int8
                ss = lax.all_gather(scale, axis)       # (K,)  f32
                w_new = w + jnp.sum(qs.astype(jnp.float32)
                                    * ss[:, None], axis=0)
            else:
                w_new = w + lax.psum(dv, axis)
            if cfg.comm_scheme == "spark_faithful":
                # alpha shipped through the master every round: all-gather
                # then re-slice own block — identity, but real traffic.
                gathered = lax.all_gather(alpha_new, axis)          # (K, n_pad)
                alpha_new = lax.dynamic_index_in_dim(
                    gathered, lax.axis_index(axis), 0, keepdims=False)
            reg = lax.psum(problem.regularizer(alpha_new * mask_k), axis)
            primal = problem.loss(w_new) + reg
            return alpha_new[None], w_new, primal

        sharded = compat.shard_map(
            shard_fn, mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis), P(None), P(None)),
            out_specs=(P(axis), P(None), P()))

        @functools.partial(jax.jit, donate_argnums=(0, 1))
        def round_fn(alpha_st, w, key_data):
            return sharded(self.A_st, self.col_sq, self.mask, alpha_st,
                           key_data[None], w)

        return round_fn

    def run_sharded(self, rounds: int, mesh: Mesh | None = None,
                    record_every: int = 1) -> History:
        cfg = self.cfg
        if mesh is None:
            mesh = compat.make_mesh((cfg.K,), ("workers",))
        round_fn = self.build_sharded_round(mesh)
        axis = mesh.axis_names[0]
        alpha, w = self.init_state()
        alpha = jax.device_put(alpha, NamedSharding(mesh, P(axis)))
        w = jax.device_put(w, NamedSharding(mesh, P(None)))
        key = jax.random.key(cfg.seed)
        hist = History(p_star=self.p_star, p_zero=self.p_zero)
        for t in range(rounds):
            key, sub = jax.random.split(key)
            alpha, w, primal = round_fn(alpha, w, jax.random.key_data(sub))
            if (t + 1) % record_every == 0 or t == rounds - 1:
                p = float(primal)
                hist.rounds.append(t + 1)
                hist.primal.append(p)
                hist.subopt.append(suboptimality(p, hist.p_star, hist.p_zero))
        self.alpha_final = part_mod.unpack_alpha(np.asarray(alpha), self.part, self.n)
        return hist

    # ------------------------------------------------------------------
    def objective_of(self, alpha_global: np.ndarray) -> float:
        return float(primal_objective(self.problem, jnp.asarray(self.A_np),
                                      self.b, jnp.asarray(alpha_global)))
