"""The paper's contribution: CoCoA-style communication-efficient distributed
GLM training (plus the mini-batch SCD and SGD baselines on the same
unified distributed-driver layer), framework-overhead modelling, and the
communication/computation trade-off machinery (the H knob)."""
from repro.core.glm import GLMProblem, primal_objective, ridge_exact, suboptimality  # noqa: F401
from repro.core.cocoa import CoCoAConfig, CoCoATrainer  # noqa: F401
from repro.core.baselines import MinibatchSCD, MinibatchSGD, SGDConfig  # noqa: F401
from repro.core.distributed import (COMM_SCHEMES, COMM_TRANSPORTS,  # noqa: F401
                                    EXCHANGE_MODES, STRAGGLER_KINDS,
                                    CommScheme, ExchangeConfig, ExchangeMode,
                                    MembershipSchedule, StragglerProfile,
                                    get_mode, get_scheme, resolve_exchange)
from repro.comm import (CODECS, COLLECTIVE_BACKENDS, CollectiveBackend,  # noqa: F401
                        UpdateCodec, get_backend, get_codec)
from repro.core.overheads import OverheadProfile, PROFILES  # noqa: F401
from repro.utils.deprecation import ReproDeprecationWarning  # noqa: F401
