"""The paper's contribution: CoCoA-style communication-efficient distributed
GLM training, framework-overhead modelling, and the communication/computation
trade-off machinery (the H knob)."""
from repro.core.glm import GLMProblem, primal_objective, ridge_exact, suboptimality  # noqa: F401
from repro.core.cocoa import CoCoAConfig, CoCoATrainer  # noqa: F401
from repro.core.overheads import OverheadProfile, PROFILES  # noqa: F401
