from repro.data.synthetic import make_glm_data  # noqa: F401
