"""Deterministic synthetic token pipeline for LM training.

A Zipfian unigram stream with short-range Markov structure gives the
model something learnable (loss drops measurably within a few hundred
steps) while staying fully offline and reproducible. Batches are
prepared host-side in numpy and sharded by the caller.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Infinite deterministic (seeded) token batch source."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int, *,
                 seed: int = 0, zipf_a: float = 1.2, markov: float = 0.7,
                 period: int = 16):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.p = p / p.sum()
        self.markov = markov
        self.period = period

    def next_batch(self) -> dict:
        B, S = self.batch, self.seq
        base = self.rng.choice(self.vocab, size=(B, S), p=self.p)
        # learnable structure: with prob `markov`, token repeats the one
        # `period` positions earlier.
        rep = self.rng.random((B, S)) < self.markov
        for t in range(self.period, S):
            base[:, t] = np.where(rep[:, t], base[:, t - self.period],
                                  base[:, t])
        tokens = base.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((B, 1), -100, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def batches(self, n: int):
        for _ in range(n):
            yield self.next_batch()
