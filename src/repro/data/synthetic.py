"""Synthetic data generators.

``make_glm_data`` stands in for the paper's webspam corpus (350k x 16.6M
sparse trigram features — not available offline). It produces a dense
matrix with webspam-like *structure* at configurable scale: highly
non-uniform column norms (trigram frequencies are Zipfian), controllable
column sparsity, controllable cross-partition correlation, and labels
from a sparse ground-truth model plus noise. The paper's findings are
about ratios and trade-off shapes, which this preserves.
"""
from __future__ import annotations

import numpy as np


def make_glm_data(m: int = 2048, n: int = 4096, *, density: float = 0.1,
                  zipf_a: float = 1.1, noise: float = 0.1,
                  truth_density: float = 0.05, seed: int = 0,
                  dtype=np.float32) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (A, b, alpha_true) with A of shape (m, n)."""
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n)).astype(dtype)
    # Zipfian column scales — webspam-like frequency skew.
    ranks = np.arange(1, n + 1, dtype=np.float64)
    scales = (ranks ** (-1.0 / zipf_a))
    scales /= scales.max()
    rng.shuffle(scales)
    A *= scales.astype(dtype)[None, :]
    # Sparsify columns.
    if density < 1.0:
        mask = rng.random((m, n)) < density
        A = np.where(mask, A, 0.0).astype(dtype)
    # Sparse ground truth + noisy labels.
    alpha_true = np.zeros(n, dtype)
    nz = rng.choice(n, size=max(1, int(truth_density * n)), replace=False)
    alpha_true[nz] = rng.standard_normal(len(nz)).astype(dtype)
    b = A @ alpha_true + noise * rng.standard_normal(m).astype(dtype)
    return A, b.astype(dtype), alpha_true
