"""jax version compatibility shims.

The codebase targets current jax (``jax.shard_map``, ``Mesh`` axis
types); CI and some dev hosts run older 0.4.x where shard_map lives in
``jax.experimental`` with a ``check_rep`` kwarg and ``make_mesh`` has no
``axis_types``. Everything that builds meshes or shard_maps goes through
here so the support matrix lives in one file.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    """Whether the default backend is a real TPU — the one shared
    switch for every Pallas kernel entry point (``kernels/ops.py``,
    ``kernels/quant.py``) and for codec dispatch."""
    return jax.default_backend() == "tpu"


def default_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel wrapper's ``interpret=None`` default: compiled
    on TPU, interpret-mode emulation everywhere else."""
    return not on_tpu() if interpret is None else interpret


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def abstract_mesh(axis_shapes, axis_names):
    """``AbstractMesh`` across the signature change: new jax takes
    ``(shape, names)``, 0.4.x takes ``((name, size), ...)`` pairs."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` across the return-type change: new
    jax returns one dict, 0.4.x returns a one-element list of dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca or {}


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
