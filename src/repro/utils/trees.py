"""Small pytree helpers shared across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves (works on ShapeDtypeStruct too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape, dtype=np.int64)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree) -> int:
    """Total element count of all array leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(int(np.prod(l.shape, dtype=np.int64)) for l in leaves
                   if hasattr(l, "shape")))


def tree_allfinite(tree) -> jax.Array:
    leaves = [jnp.all(jnp.isfinite(l)) for l in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)]
    return jnp.all(jnp.stack(leaves)) if leaves else jnp.asarray(True)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)
