from repro.utils import hlo, trees  # noqa: F401
