"""Aggregate collective-traffic view over optimized HLO text.

The parsing itself lives in :mod:`repro.analysis.graph` (per-op records
with dtypes, replica groups, channel ids, source-target pairs); this
module keeps the original aggregate API — :class:`CollectiveStats`,
:func:`parse_collectives`, :func:`collective_bytes` — as a thin view
over the lifted graph. New code should use the graph directly.

Delegating fixed three long-standing parser gaps (regression corpus
under ``tests/data/hlo/``): 4-bit wire dtypes (``s4``/``u4``) counted
as 0 bytes, async ``-start``/``-done`` pairs double-counted the operand
into the start op's tuple result, and tuple results whose layouts
contain parens (``{0:T(256)}``) were truncated by the old one-regex
type scan.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.analysis.graph import COLLECTIVE_OPS, lift_hlo  # noqa: F401


@dataclass
class CollectiveStats:
    """Aggregate collective traffic found in one HLO module."""
    # op kind -> (count, total operand bytes, total result bytes)
    by_kind: dict = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> int:
        return sum(v[1] for v in self.by_kind.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(v[2] for v in self.by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(v[0] for v in self.by_kind.values())

    def add(self, kind: str, operand_bytes: int, result_bytes: int) -> None:
        c, ob, rb = self.by_kind.get(kind, (0, 0, 0))
        self.by_kind[kind] = (c + 1, ob + operand_bytes, rb + result_bytes)

    def merged(self, other: "CollectiveStats", scale: float = 1.0) -> "CollectiveStats":
        out = CollectiveStats(dict(self.by_kind))
        for k, (c, ob, rb) in other.by_kind.items():
            c0, ob0, rb0 = out.by_kind.get(k, (0, 0, 0))
            out.by_kind[k] = (c0 + int(c * scale), ob0 + int(ob * scale), rb0 + int(rb * scale))
        return out

    def summary(self) -> str:
        lines = []
        for k, (c, ob, rb) in sorted(self.by_kind.items()):
            lines.append(f"{k:20s} n={c:4d} operand={ob/1e6:10.2f}MB result={rb/1e6:10.2f}MB")
        lines.append(f"{'TOTAL':20s} n={self.total_count:4d} "
                     f"operand={self.total_operand_bytes/1e6:10.2f}MB "
                     f"result={self.total_result_bytes/1e6:10.2f}MB")
        return "\n".join(lines)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand/result sizes of every collective op in optimized HLO
    text (aggregate view of :func:`repro.analysis.graph.lift_hlo`)."""
    stats = CollectiveStats()
    for op in lift_hlo(hlo_text).collectives:
        stats.add(op.kind, op.operand_bytes, op.result_bytes)
    return stats


def collective_bytes(hlo_text: str) -> int:
    """Convenience: total operand bytes moved by collectives."""
    return parse_collectives(hlo_text).total_operand_bytes
