"""Parse optimized HLO text for collective-communication traffic.

``compiled.as_text()`` (post-SPMD-partitioning HLO) is the only place the
GSPMD-inserted collectives are visible.  Operand types are not inline in
the text (``all-reduce(%wrapped_reduce)``), so we first build a symbol
table mapping every instruction name to its result byte size, then sum
operand sizes for every collective op.

Ops counted: all-reduce, all-gather, reduce-scatter, all-to-all,
collective-permute (and their -start async variants).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# `%name = dtype[d0,d1]{layout} opcode(...)`  (tuple results handled below)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^=]*?\)|[\w\[\],{}/:#\s]*?)\s+"
    r"(?P<op>[\w\-]+)\((?P<operands>.*)$")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]\d*[a-z]*\d*(?:e\d+m\d+(?:fn)?)?)\[(?P<dims>[\d,]*)\]")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    """Aggregate collective traffic found in one HLO module."""
    # op kind -> (count, total operand bytes, total result bytes)
    by_kind: dict = field(default_factory=dict)

    @property
    def total_operand_bytes(self) -> int:
        return sum(v[1] for v in self.by_kind.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(v[2] for v in self.by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(v[0] for v in self.by_kind.values())

    def add(self, kind: str, operand_bytes: int, result_bytes: int) -> None:
        c, ob, rb = self.by_kind.get(kind, (0, 0, 0))
        self.by_kind[kind] = (c + 1, ob + operand_bytes, rb + result_bytes)

    def merged(self, other: "CollectiveStats", scale: float = 1.0) -> "CollectiveStats":
        out = CollectiveStats(dict(self.by_kind))
        for k, (c, ob, rb) in other.by_kind.items():
            c0, ob0, rb0 = out.by_kind.get(k, (0, 0, 0))
            out.by_kind[k] = (c0 + int(c * scale), ob0 + int(ob * scale), rb0 + int(rb * scale))
        return out

    def summary(self) -> str:
        lines = []
        for k, (c, ob, rb) in sorted(self.by_kind.items()):
            lines.append(f"{k:20s} n={c:4d} operand={ob/1e6:10.2f}MB result={rb/1e6:10.2f}MB")
        lines.append(f"{'TOTAL':20s} n={self.total_count:4d} "
                     f"operand={self.total_operand_bytes/1e6:10.2f}MB "
                     f"result={self.total_result_bytes/1e6:10.2f}MB")
        return "\n".join(lines)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand/result sizes of every collective op in optimized HLO text."""
    # Pass 1: symbol table  name -> result bytes.
    sizes: dict[str, int] = {}
    records = []  # (kind, operand_names, result_bytes)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group("name"), m.group("type"), m.group("op")
        sizes[name] = _type_bytes(type_str)
        base_op = op.replace("-start", "").replace("-done", "")
        if base_op in COLLECTIVE_OPS and not op.endswith("-done"):
            # operands: comma-separated %refs before the `)` that closes the call
            ops_str = m.group("operands")
            depth = 1
            out = []
            for ch in ops_str:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                out.append(ch)
            operand_names = re.findall(r"%([\w.\-]+)", "".join(out))
            records.append((base_op, operand_names, sizes[name]))
    stats = CollectiveStats()
    for kind, operand_names, result_bytes in records:
        ob = sum(sizes.get(n, 0) for n in operand_names)
        stats.add(kind, ob, result_bytes)
    return stats


def collective_bytes(hlo_text: str) -> int:
    """Convenience: total operand bytes moved by collectives."""
    return parse_collectives(hlo_text).total_operand_bytes
