"""Repro's own deprecation channel.

Deprecated surfaces (the scattered ``comm_scheme=``/``exchange_mode=``
knobs replaced by :class:`repro.core.distributed.ExchangeConfig`, the
``get_scheme``/``get_mode`` lookups) warn through a *dedicated*
``DeprecationWarning`` subclass so the test suite can turn exactly these
warnings — and not the interpreter's or jax's — into errors
(``filterwarnings = error::repro.utils.deprecation.ReproDeprecationWarning``
in pyproject.toml). That lint is what keeps the old spellings from
creeping back into the repo's own code and tests while third-party
deprecation noise stays non-fatal.
"""
from __future__ import annotations

import warnings


class ReproDeprecationWarning(DeprecationWarning):
    """A deprecated repro API surface was used (one release of warning
    before removal)."""


def warn_deprecated(message: str, *, stacklevel: int = 3) -> None:
    """Emit a :class:`ReproDeprecationWarning` pointing at the caller's
    caller (the default ``stacklevel=3`` skips this helper and the
    deprecated shim itself)."""
    warnings.warn(message, ReproDeprecationWarning, stacklevel=stacklevel)
