from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, MLAConfig, EncDecConfig, ShapeConfig,
    SHAPES, input_specs, padded_vocab,
)
from repro.configs.registry import ARCHS, get_config, list_archs  # noqa: F401
