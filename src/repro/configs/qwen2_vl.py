"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (vision tower stubbed).
[arXiv:2409.12191] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152_064,
    rope_style="mrope",
    rope_theta=1_000_000.0,
    attn_bias=True,           # qwen2 qkv bias
    mlp_act="silu",
    mlp_gated=True,
    num_patch_tokens=1024,    # stub vision frontend token budget
    long_context="swa",
)
