"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern.
[arXiv:2402.19427 (Griffin)] 38L(~) d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000. Pattern: (rglru, rglru, local-attn) cycled; 36 layers = 12
full cycles (38 rounded to the pattern period, noted in DESIGN.md)."""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=36,             # 38 in the card; rounded to 12 x (2:1) cycles
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "attn_local"),
    rope_style="partial",
    rope_frac=0.5,
    mlp_act="gelu",
    mlp_gated=True,
    rglru=RGLRUConfig(lru_width=4096, d_conv=4, local_window=2048),
    logit_softcap=30.0,
    long_context="native",     # recurrent + local attn: natively sub-quadratic
)
