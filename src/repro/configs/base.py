"""Config schema for every architecture + the four assigned input shapes.

All configs are plain frozen dataclasses; ``input_specs`` produces
``jax.ShapeDtypeStruct`` stand-ins (no allocation) for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    num_shared: int = 0           # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    first_k_dense: int = 0        # leading dense layers (deepseek-v3: 3)
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder stack for enc-dec models (whisper). The conv/mel frontend
    is a stub: input_specs feeds precomputed frame embeddings."""
    num_layers: int = 4
    source_len: int = 1500        # whisper 30s @ 2x conv downsample


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 P
    chunk: int = 128              # SSD chunk length
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int | None = None  # defaults to d_model
    d_conv: int = 4
    local_window: int = 2048      # window of the interleaved local-attn


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    source: str                    # citation for the numbers
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # block pattern, cycled over layers: "attn" | "attn_local" | "rglru" | "ssd"
    block_pattern: tuple = ("attn",)
    # attention flavour
    rope_style: str = "full"       # full | partial | 2d | mrope | none
    rope_frac: float = 1.0         # fraction of head_dim that rotates
    rope_theta: float = 10000.0
    attn_bias: bool = False
    sliding_window: int | None = None   # set -> SWA for long-context decode
    # mlp
    mlp_act: str = "silu"          # silu | gelu | relu2
    mlp_gated: bool = True
    mlp_bias: bool = False
    parallel_block: bool = False   # command-r style attn||mlp
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    logit_softcap: float | None = None
    # family extensions
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    encdec: EncDecConfig | None = None
    mtp_depth: int = 0             # deepseek-v3 multi-token prediction heads
    # vlm stub frontend: number of prepended patch-embedding positions
    num_patch_tokens: int = 0
    dtype: str = "bfloat16"
    # long-context policy: "native" (sub-quadratic already), "swa" (use
    # sliding_window for long_500k), "skip" (documented skip)
    long_context: str = "swa"

    @property
    def attn_free(self) -> bool:
        return all(b == "ssd" for b in self.block_pattern)

    def reduced(self) -> "ModelConfig":
        """2-layer, narrow variant of the same family for CPU smoke tests."""
        pattern_len = len(self.block_pattern)
        layers = max(2, pattern_len)
        kw = dict(
            num_layers=layers,
            d_model=256,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=512,
            vocab_size=512,
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, num_experts=4,
                                top_k=min(self.moe.top_k, 2), d_expert=128,
                                first_k_dense=min(self.moe.first_k_dense, 1))
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=32, chunk=32)
        if self.rglru is not None:
            kw["rglru"] = RGLRUConfig(lru_width=256, d_conv=4, local_window=64)
        if self.encdec is not None:
            kw["encdec"] = EncDecConfig(num_layers=2, source_len=64)
        if self.sliding_window is not None:
            kw["sliding_window"] = 64
        kw["name"] = self.name + "-reduced"
        return replace(self, **kw)


def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    """Megatron-style vocab padding so the vocab dim shards over the
    16-way model axis (whisper 51865 -> 51968, mamba2 50280 -> 50432)."""
    v = cfg.vocab_size
    return ((v + multiple - 1) // multiple) * multiple


# ----------------------------------------------------------------------
# The four assigned input shapes.
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill: token ids (+ labels for train); VLM additionally gets
    stub patch embeddings, audio gets stub encoder frame embeddings.
    decode: one new token per sequence (the KV cache / SSM state is part
    of the step *state*, built separately by serve.cache.init_cache).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one token, cache of length S in the step state
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["positions"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.family == "vlm" and shape.kind != "decode":
        # stub vision frontend: pre-projected patch embeddings that the
        # backbone interleaves with text (counted inside S).
        n_patch = min(cfg.num_patch_tokens or 256, S // 2)
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, n_patch, cfg.d_model), jnp.bfloat16)
        specs["patch_positions"] = jax.ShapeDtypeStruct((B, n_patch, 3), i32)
    if cfg.family == "audio":
        # stub conv/mel frontend: encoder frame embeddings.
        specs["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encdec.source_len, cfg.d_model), jnp.bfloat16)
    return specs
