"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "qwen2-vl-72b": "repro.configs.qwen2_vl",
    "deepseek-v3-671b": "repro.configs.deepseek_v3",
    "chatglm3-6b": "repro.configs.chatglm3",
    "nemotron-4-15b": "repro.configs.nemotron4",
    "recurrentgemma-9b": "repro.configs.recurrentgemma",
    "tinyllama-1.1b": "repro.configs.tinyllama",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "mamba2-2.7b": "repro.configs.mamba2",
    "command-r-35b": "repro.configs.command_r",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)
