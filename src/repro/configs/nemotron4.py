"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP (non-gated), partial rope.
[arXiv:2402.16819] 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    source="arXiv:2402.16819",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256_000,
    rope_style="partial",
    rope_frac=0.5,
    mlp_act="relu2",
    mlp_gated=False,
    norm="layernorm",
    long_context="swa",
)
