"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060] 64L d_model=2560 ssm_state=128 vocab=50280 (padded
50432). d_inner = 2*d_model = 5120, head_dim 64 -> 80 SSD heads."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    num_heads=80,              # = d_inner / head_dim (informational)
    num_kv_heads=80,
    head_dim=64,
    d_ff=0,                    # no separate channel MLP
    vocab_size=50_280,
    block_pattern=("ssd",),
    rope_style="none",
    mlp_act="silu",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk=128, n_groups=1),
    long_context="native",     # recurrent decode: O(1) per token
)
