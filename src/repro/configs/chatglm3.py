"""chatglm3-6b [dense] — RoPE 2d (half-dim rotary), GQA kv=2.
[arXiv:2406.12793] 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65_024,
    rope_style="2d",          # rotary over half the head dims
    attn_bias=True,           # chatglm qkv bias
    mlp_act="silu",
    mlp_gated=True,
    long_context="swa",
)
