"""llama4-maverick-400b-a17b [moe] — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E family; Maverick: 128 experts top-1]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
(+1 shared expert, llama4-style)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    rope_style="full",
    rope_theta=500_000.0,
    mlp_act="silu",
    mlp_gated=True,
    moe=MoEConfig(num_experts=128, top_k=1, d_expert=8192, num_shared=1),
    long_context="swa",
    sliding_window=None,   # enabled only for the long_500k variant
)
