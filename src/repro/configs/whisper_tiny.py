"""whisper-tiny [audio] — enc-dec, conv/mel frontend STUBBED.
[arXiv:2212.04356] 4L d_model=384 6H d_ff=1536 vocab=51865 (padded 51968).
long_500k is SKIPPED for this arch (enc-dec with <=1.5k source frames and
a 448-token real decoder; a 512k-token decode is architecturally
meaningless) — see DESIGN.md §6."""
from repro.configs.base import ModelConfig, EncDecConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,              # decoder layers; encoder in encdec
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    rope_style="none",
    attn_bias=True,
    mlp_act="gelu",
    mlp_gated=False,
    mlp_bias=True,
    norm="layernorm",
    tie_embeddings=True,
    encdec=EncDecConfig(num_layers=4, source_len=1500),
    long_context="skip",
)
