"""command-r-35b [dense] — GQA, no-bias, parallel attn||mlp block, tied
embeddings. [hf:CohereForAI/c4ai-command-r-v01]
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256_000,
    rope_style="full",
    rope_theta=8_000_000.0,
    mlp_act="silu",
    mlp_gated=True,
    parallel_block=True,
    norm="layernorm",
    tie_embeddings=True,
    long_context="swa",
)
