"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437] 61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
The config's "GQA kv=128" reflects MLA's 128 query heads; KV is
latent-compressed (kv_lora_rank=512) — implemented as true MLA."""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    source="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # nominal; MLA latent cache is what's stored
    head_dim=128,
    d_ff=18432,                # dense-layer FFN width (first_k_dense layers)
    vocab_size=129_280,
    rope_style="full",
    rope_theta=10_000.0,
    mlp_act="silu",
    mlp_gated=True,
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  first_k_dense=3),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    mtp_depth=1,
    long_context="swa",
)
