"""Pallas TPU kernel: fused top-k magnitude select for the ``topk``
codec's encode (the PR 9 follow-up — ``lax.top_k`` was the only codec
without a fused encode path).

The jnp oracle (``TopKCodec.encode_ref``) sorts all L magnitudes to
keep k of them. This kernel instead runs k argmax+mask sweeps over the
magnitude row held in VMEM — O(k*L) VPU work with no sort network, no
HBM round-trips, and k << L by construction (the codec keeps ~1% of
the entries). Selection is EXACT, so the outputs are bit-identical to
the oracle:

  * magnitudes are compared as ``jnp.abs`` of the same f32 input;
  * ties break to the lowest index (the first-occurrence argmax below
    matches ``lax.top_k``'s stable ordering);
  * selected values are read out exactly (a masked max against -inf,
    not an arithmetic reduction that could re-round);
  * the threshold is the k-th (last-selected) magnitude, the same
    ``mags[k-1]`` the oracle ships.

Padded lanes carry magnitude -1 so they can never be selected (real
magnitudes are >= 0); consumed lanes are masked the same way. The
wrapper pads L and k to the 128-lane tile and slices the outputs, runs
compiled on TPU and in interpret mode everywhere else — the same
convention as ``quantize_pack_*``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

_LANE = 128  # TPU lane width


def _topk_kernel(k: int, L: int, x_ref, v_ref, i_ref, t_ref):
    x = x_ref[...]                                       # (1, Lp)
    lane = lax.broadcasted_iota(jnp.int32, x.shape, 1)
    mags = jnp.where(lane < L, jnp.abs(x), -1.0)
    out_lane = lax.broadcasted_iota(jnp.int32, v_ref.shape, 1)
    vals = jnp.zeros(v_ref.shape, jnp.float32)
    idxs = jnp.zeros(i_ref.shape, jnp.int32)

    def body(i, carry):
        mags, vals, idxs, _ = carry
        m = jnp.max(mags)                                # k-th mag at i=k-1
        sel = jnp.min(jnp.where(mags == m, lane, L))     # first occurrence
        v = jnp.max(jnp.where(lane == sel, x, -jnp.inf))
        vals = jnp.where(out_lane == i, v, vals)
        idxs = jnp.where(out_lane == i, sel, idxs)
        mags = jnp.where(lane == sel, -1.0, mags)        # consume the lane
        return mags, vals, idxs, m

    _, vals, idxs, thr = lax.fori_loop(
        0, k, body, (mags, vals, idxs, jnp.float32(0.0)))
    v_ref[...] = vals
    i_ref[...] = idxs
    t_ref[0, 0] = thr


def _pad_lanes(x: jax.Array) -> jax.Array:
    pad = -x.shape[-1] % _LANE
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_select(dv: jax.Array, k: int, *, interpret: bool | None = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k magnitude select of a 1-D f32 update: ``(values (k,) f32,
    indices (k,) int32, threshold f32)``, bit-identical to
    ``TopKCodec.encode_ref``."""
    from repro.utils import compat
    interpret = compat.default_interpret(interpret)
    L = dv.shape[0]
    assert 1 <= k <= L, (k, L)
    x = _pad_lanes(dv.astype(jnp.float32))[None, :]
    kp = -(-k // _LANE) * _LANE
    vals, idxs, thr = pl.pallas_call(
        functools.partial(_topk_kernel, k, L),
        out_shape=[jax.ShapeDtypeStruct((1, kp), jnp.float32),
                   jax.ShapeDtypeStruct((1, kp), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return vals[0, :k], idxs[0, :k], thr[0, 0]
