"""Pallas TPU kernel: fused absmax quantize + nibble pack for the
``compressed`` exchange's wire codecs.

The jnp encode path (``repro.comm.codec``, the oracle) lowers to a
chain of HBM-materialized f32 intermediates — ``abs``, the scaled
vector, the rounded vector, the clipped vector — before the cast and
(for int4) the pack. At update-vector scale that is 4-5 redundant HBM
round-trips for what is one streaming pass of VPU work. This kernel
keeps the whole update resident in VMEM and does absmax-reduce, scale,
round, clip, bias and pack in a single grid step:

  * int8: (1, L) f32 in -> (1, L) int8 + (1, 1) f32 scale out.
  * int4: (2, L/2) f32 in (the codec's split-half pairing: element i
    pairs with element i + L/2, so "pack" is an elementwise
    ``lo | hi << 4`` of the two sublane rows — no strided gathers) ->
    (1, L/2) uint8 + (1, 1) f32 scale out.
  * int2: (4, L/4) f32 in (split-quarter pairing: element i pairs with
    i + L/4, i + 2L/4, i + 3L/4, so "pack" is an elementwise two-bit
    shift-or of the four sublane rows) -> (1, L/4) uint8 + (1, 1) f32
    scale out.

The wrappers pad the lane dimension to 128 with zeros (absmax is
unaffected; padded elements quantize to the zero nibble and are sliced
off), run compiled on TPU and in interpret mode everywhere else — the
same convention as ``scd_pallas`` — and are bit-identical to the
codec's ``encode_ref`` oracle (pinned by tests and the ``kernels``
benchmark).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.comm.codec import (INT2_QMAX, INT2_SCALE_MUL, INT4_QMAX,
                              INT4_SCALE_DIV, INT8_QMAX)
from repro.utils import compat

_LANE = 128  # TPU lane width: pad the streamed dimension to a multiple


def _quant_int8_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax / INT8_QMAX + 1e-30, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -INT8_QMAX,
                          INT8_QMAX).astype(jnp.int8)
    s_ref[0, 0] = scale


def _quant_int4_kernel(x_ref, p_ref, s_ref):
    x = x_ref[...]                                   # (2, half)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax / INT4_SCALE_DIV, 1.0)
    q = jnp.clip(jnp.round(x / scale), -INT4_QMAX,
                 INT4_QMAX).astype(jnp.int32) + 8    # biased nibbles
    p_ref[...] = (q[0:1, :] | (q[1:2, :] << 4)).astype(jnp.uint8)
    s_ref[0, 0] = scale


def _quant_int2_kernel(x_ref, p_ref, s_ref):
    x = x_ref[...]                                   # (4, quarter)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax > 0, absmax * INT2_SCALE_MUL, 1.0)
    q = jnp.clip(jnp.round(x / scale), -INT2_QMAX,
                 INT2_QMAX).astype(jnp.int32) + 2    # biased 2-bit codes
    p_ref[...] = (q[0:1, :] | (q[1:2, :] << 2) | (q[2:3, :] << 4)
                  | (q[3:4, :] << 6)).astype(jnp.uint8)
    s_ref[0, 0] = scale


def _pad_lanes(x: jax.Array) -> jax.Array:
    pad = -x.shape[-1] % _LANE
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pack_int8(dv: jax.Array, *, interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Fused int8 encode of a 1-D f32 update: ``(q (L,) int8, scale)``,
    bit-identical to ``Int8Codec.encode_ref``."""
    interpret = compat.default_interpret(interpret)
    L = dv.shape[0]
    x = _pad_lanes(dv.astype(jnp.float32))[None, :]
    q, scale = pl.pallas_call(
        _quant_int8_kernel,
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.int8),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[0, :L], scale[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pack_int4(dv: jax.Array, *, interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Fused int4 encode of a 1-D f32 update: ``(packed (ceil(L/2),)
    uint8, scale)``, bit-identical to ``Int4Codec.encode_ref``."""
    interpret = compat.default_interpret(interpret)
    L = dv.shape[0]
    half = -(-L // 2)
    dv = dv.astype(jnp.float32)
    dv = jnp.concatenate([dv, jnp.zeros((2 * half - L,), dv.dtype)])
    x = _pad_lanes(dv.reshape(2, half))              # split-half rows
    packed, scale = pl.pallas_call(
        _quant_int4_kernel,
        out_shape=[jax.ShapeDtypeStruct((1, x.shape[1]), jnp.uint8),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return packed[0, :half], scale[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pack_int2(dv: jax.Array, *, interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Fused int2 encode of a 1-D f32 update: ``(packed (ceil(L/4),)
    uint8, scale)``, bit-identical to ``Int2Codec.encode_ref``."""
    interpret = compat.default_interpret(interpret)
    L = dv.shape[0]
    quarter = -(-L // 4)
    dv = dv.astype(jnp.float32)
    dv = jnp.concatenate([dv, jnp.zeros((4 * quarter - L,), dv.dtype)])
    x = _pad_lanes(dv.reshape(4, quarter))           # split-quarter rows
    packed, scale = pl.pallas_call(
        _quant_int2_kernel,
        out_shape=[jax.ShapeDtypeStruct((1, x.shape[1]), jnp.uint8),
                   jax.ShapeDtypeStruct((1, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return packed[0, :quarter], scale[0, 0]
