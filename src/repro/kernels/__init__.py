# The paper's compute hot-spot is the local SCD solver, which it
# offloads to optimized native (C++) modules — here that role is played
# by a Pallas TPU kernel (scd.py) with a pure-jnp oracle (ref.py).
from repro.kernels.ops import scd_steps_kernel  # noqa: F401
from repro.kernels.ref import scd_steps_ref  # noqa: F401
