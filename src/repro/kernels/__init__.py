# The paper's compute hot-spot is the local SCD solver, which it
# offloads to optimized native (C++) modules — here that role is played
# by a Pallas TPU kernel (scd.py) with a pure-jnp oracle (ref.py). The
# other hot paths are the compressed exchange's two wire sides: encode
# is fused by the quantize+pack kernels (quant.py) and the top-k select
# kernel (topk.py); decode+reduce of the all-gathered payload is fused
# by the dequant kernels (dequant.py). Every kernel's oracle is the
# codec layer, re-exported through ref.py.
from repro.kernels.dequant import (decode_mean_int2,  # noqa: F401
                                   decode_mean_int4, decode_mean_int8,
                                   decode_reduce_int2, decode_reduce_int4,
                                   decode_reduce_int8)
from repro.kernels.ops import scd_steps_kernel  # noqa: F401
from repro.kernels.quant import (quantize_pack_int2,  # noqa: F401
                                 quantize_pack_int4, quantize_pack_int8)
from repro.kernels.ref import (decode_stacked_ref,  # noqa: F401
                               quantize_pack_int2_ref,
                               quantize_pack_int4_ref,
                               quantize_pack_int8_ref, scd_steps_ref,
                               topk_select_ref)
from repro.kernels.topk import topk_select  # noqa: F401
