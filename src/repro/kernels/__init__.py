# The paper's compute hot-spot is the local SCD solver, which it
# offloads to optimized native (C++) modules — here that role is played
# by a Pallas TPU kernel (scd.py) with a pure-jnp oracle (ref.py). The
# other hot path is the compressed exchange's wire encode, fused by the
# quantize+pack kernel (quant.py) whose oracle is the codec layer.
from repro.kernels.ops import scd_steps_kernel  # noqa: F401
from repro.kernels.quant import (quantize_pack_int2,  # noqa: F401
                                 quantize_pack_int4, quantize_pack_int8)
from repro.kernels.ref import (quantize_pack_int2_ref,  # noqa: F401
                               quantize_pack_int4_ref,
                               quantize_pack_int8_ref, scd_steps_ref)
