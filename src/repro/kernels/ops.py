"""Jitted public wrapper around the Pallas SCD kernel.

``scd_steps_kernel`` matches the contract of the pure-jnp oracle
``repro.kernels.ref.scd_steps_ref`` exactly, so the two are drop-in
interchangeable as CoCoA local solvers (``CoCoAConfig.solver``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scd import scd_pallas
from repro.utils import compat


@functools.partial(jax.jit,
                   static_argnames=("sigma", "lam", "eta", "h_blk", "interpret"))
def scd_steps_kernel(A_k: jax.Array, col_sq: jax.Array, alpha_k: jax.Array,
                     w: jax.Array, idx: jax.Array, *, sigma: float,
                     lam: float, eta: float, h_blk: int = 128,
                     interpret: bool | None = None):
    """H SCD steps on one worker's column block via the Pallas kernel.

    Same signature/returns as ``repro.core.solvers.scd_steps``:
      A_k (m, n_local), col_sq (n_local,), alpha_k (n_local,), w (m,),
      idx (H,) int32  ->  (delta_v (m,), alpha_new (n_local,)).
    """
    interpret = compat.default_interpret(interpret)
    H = idx.shape[0]
    h_blk = min(h_blk, H)
    pad = (-H) % h_blk
    if pad:
        # Padded steps gather column 0 but carry csq=0 -> exact no-ops.
        idx_p = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
        csq_g = jnp.concatenate([col_sq[idx], jnp.zeros((pad,), col_sq.dtype)])
    else:
        idx_p, csq_g = idx, col_sq[idx]
    cols = jnp.take(A_k, idx_p, axis=1).T            # (H', m) pre-gather
    csq_g = jnp.where(jnp.arange(idx_p.shape[0]) < H, csq_g, 0.0)
    alpha2d = alpha_k.astype(jnp.float32)[:, None]
    w2d = w[None, :]
    alpha_new, rho = scd_pallas(
        cols, csq_g[:, None].astype(jnp.float32), idx_p[:, None],
        alpha2d, w2d, sigma=float(sigma),
        lam_eta=float(lam * eta), lam_l1=float(lam * (1.0 - eta)),
        h_blk=h_blk, interpret=interpret)
    delta_v = (rho[0] - w) / jnp.asarray(sigma, rho.dtype)
    return delta_v.astype(w.dtype), alpha_new[:, 0].astype(alpha_k.dtype)
