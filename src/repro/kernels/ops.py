"""Jitted public wrapper around the Pallas SCD kernel.

``scd_steps_kernel`` matches the contract of the pure-jnp oracle
``repro.kernels.ref.scd_steps_ref`` exactly, so the two are drop-in
interchangeable as CoCoA local solvers (``CoCoAConfig.solver``). The
wrapper's only job is the one XLA gather that turns the random-access
column visits into the dense (H, m) stream the kernel pipelines;
padding, lane tiling and block sizing all live in ``scd_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scd import scd_pallas


@functools.partial(jax.jit,
                   static_argnames=("sigma", "lam", "eta", "h_blk", "interpret"))
def scd_steps_kernel(A_k: jax.Array, col_sq: jax.Array, alpha_k: jax.Array,
                     w: jax.Array, idx: jax.Array, *, sigma: float,
                     lam: float, eta: float, h_blk: int | None = None,
                     interpret: bool | None = None):
    """H SCD steps on one worker's column block via the Pallas kernel.

    Same signature/returns as ``repro.core.solvers.scd_steps``:
      A_k (m, n_local), col_sq (n_local,), alpha_k (n_local,), w (m,),
      idx (H,) int32  ->  (delta_v (m,), alpha_new (n_local,)).
    ``h_blk=None`` lets the kernel size its grid block from the VMEM
    budget.
    """
    cols = jnp.take(A_k, idx, axis=1).T              # (H, m) pre-gather
    alpha_new, rho = scd_pallas(
        cols, col_sq[idx], idx, alpha_k.astype(jnp.float32), w,
        sigma=float(sigma), lam_eta=float(lam * eta),
        lam_l1=float(lam * (1.0 - eta)), h_blk=h_blk,
        interpret=interpret)
    delta_v = (rho - w) / jnp.asarray(sigma, rho.dtype)
    return delta_v.astype(w.dtype), alpha_new.astype(alpha_k.dtype)
