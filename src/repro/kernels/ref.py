"""Pure-jnp oracles for the Pallas kernels.

The contracts are identical to the algorithmic sources of truth —
``repro.core.solvers.scd_steps`` for the SCD solver and the
``repro.comm.codec`` encode paths for the fused quantize+pack kernel —
re-exported here so kernel tests and benchmarks depend only on
``repro.kernels``.
"""
from repro.comm.codec import CODECS as _CODECS
from repro.core.solvers import scd_steps as scd_steps_ref  # noqa: F401
from repro.core.solvers import soft_threshold  # noqa: F401

quantize_pack_int8_ref = _CODECS["int8"].encode_ref
quantize_pack_int4_ref = _CODECS["int4"].encode_ref
quantize_pack_int2_ref = _CODECS["int2"].encode_ref
