"""Pure-jnp oracles for the Pallas kernels.

The contracts are identical to the algorithmic sources of truth —
``repro.core.solvers.scd_steps`` for the SCD solver and the
``repro.comm.codec`` encode/decode paths for the fused quantize+pack,
decode+reduce, and top-k select kernels — re-exported here so kernel
tests and benchmarks depend only on ``repro.kernels``.
"""
from repro.comm.codec import CODECS as _CODECS
from repro.core.solvers import scd_steps as scd_steps_ref  # noqa: F401
from repro.core.solvers import soft_threshold  # noqa: F401

quantize_pack_int8_ref = _CODECS["int8"].encode_ref
quantize_pack_int4_ref = _CODECS["int4"].encode_ref
quantize_pack_int2_ref = _CODECS["int2"].encode_ref

from repro.comm.codec import get_codec as _get_codec

topk_select_ref = _get_codec("topk").encode_ref


def decode_stacked_ref(codec: str, parts, length: int, *,
                       mean: bool = True):
    """Oracle for the fused decode+reduce kernels: decode the
    all-gathered ``(K, wire)`` payload one worker row at a time and
    accumulate SEQUENTIALLY in canonical worker order (mean = sum times
    the f32-rounded 1/K) — the exact op sequence the Pallas kernels in
    ``repro.kernels.dequant`` replay, so kernel and oracle are
    bit-identical."""
    return _CODECS[codec].decode_reduce_ref(parts, length, mean=mean)
