"""Pure-jnp oracle for the Pallas SCD kernel.

The contract is identical to ``repro.core.solvers.scd_steps`` (which is
the algorithmic source of truth); re-exported here so kernel tests and
benchmarks depend only on ``repro.kernels``.
"""
from repro.core.solvers import scd_steps as scd_steps_ref  # noqa: F401
from repro.core.solvers import soft_threshold  # noqa: F401
