"""Pallas TPU kernel for the CoCoA local SCD solver.

This is the TPU-native analogue of the paper's "offload the hot loop to
an optimized C++ module": the H sequential coordinate-descent steps run
entirely out of VMEM, with the per-step column data streamed
HBM -> VMEM by the Pallas pipeline.

TPU adaptation (vs the CPU/C++ original):
  * SCD gathers one column c_j per step. Random-access gathers from HBM
    inside a TPU kernel would serialize on DMA latency, so the caller
    pre-gathers the H visited columns into a dense (H, m) matrix with a
    single XLA gather; the kernel then *streams* that matrix through
    VMEM in (H_blk, m) tiles via BlockSpec — sequential-friendly DMA,
    double-buffered by the Pallas pipeline.
  * The live state — the residual rho (m,) and the local coordinate
    block alpha (n_local,) — is kept resident in VMEM across all grid
    steps (constant index_map outputs), exactly the paper's "persistent
    local memory" idea pushed down into the memory hierarchy
    (HBM -> VMEM instead of master -> worker).
  * State vectors are shaped 2-D ((n,1) / (1,m)) so per-step dynamic
    indexing lands on the sublane dimension, not the lane dimension.
  * Reductions (rho . c_j) are VPU work; accumulation in f32 regardless
    of the streaming dtype.

The grid is sequential on TPU, which the carried-in-VMEM state relies
on. Padded tail steps (csq == 0) are exact no-ops by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _scd_kernel(sigma: float, lam_eta: float, lam_l1: float, h_blk: int,
                cols_ref, csq_ref, idx_ref, alpha_in_ref, w_ref,
                alpha_ref, rho_ref):
    """One grid step: h_blk sequential SCD updates on the VMEM state."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        alpha_ref[...] = alpha_in_ref[...]
        rho_ref[...] = w_ref[...].astype(jnp.float32)

    def body(s, _):
        j = idx_ref[s, 0]
        c = cols_ref[s, :].astype(jnp.float32)          # (m,)
        csq = csq_ref[s, 0].astype(jnp.float32)
        a = alpha_ref[j, 0]
        rho = rho_ref[0, :]
        denom = sigma * csq + lam_eta
        z_tilde = (sigma * csq * a - jnp.dot(rho, c)) / denom
        z = jnp.sign(z_tilde) * jnp.maximum(jnp.abs(z_tilde) - lam_l1 / denom, 0.0)
        z = jnp.where(csq > 0, z, a)
        alpha_ref[j, 0] = z
        rho_ref[0, :] = rho + (sigma * (z - a)) * c
        return 0

    lax.fori_loop(0, h_blk, body, 0)


@functools.partial(jax.jit, static_argnames=("sigma", "lam_eta", "lam_l1",
                                             "h_blk", "interpret"))
def scd_pallas(cols: jax.Array, csq: jax.Array, idx: jax.Array,
               alpha: jax.Array, w: jax.Array, *, sigma: float,
               lam_eta: float, lam_l1: float, h_blk: int = 128,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Run H = cols.shape[0] SCD steps (H must be a multiple of h_blk).

    Args:
      cols:  (H, m) pre-gathered columns, streaming dtype (f32/bf16).
      csq:   (H, 1) squared norms of the gathered columns, f32.
      idx:   (H, 1) int32 local coordinate index per step.
      alpha: (n_local, 1) f32 local coordinates.
      w:     (1, m) round-start shared residual.
    Returns:
      (alpha_new (n_local,1) f32, rho (1,m) f32).
    """
    H, m = cols.shape
    assert H % h_blk == 0, (H, h_blk)
    n_local = alpha.shape[0]
    grid = (H // h_blk,)
    kernel = functools.partial(_scd_kernel, float(sigma), float(lam_eta),
                               float(lam_l1), h_blk)
    alpha_out, rho = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((h_blk, m), lambda i: (i, 0)),      # column stream
            pl.BlockSpec((h_blk, 1), lambda i: (i, 0)),      # csq stream
            pl.BlockSpec((h_blk, 1), lambda i: (i, 0)),      # idx stream
            pl.BlockSpec((n_local, 1), lambda i: (0, 0)),    # alpha (resident)
            pl.BlockSpec((1, m), lambda i: (0, 0)),          # w (resident)
        ],
        out_specs=[
            pl.BlockSpec((n_local, 1), lambda i: (0, 0)),    # alpha out
            pl.BlockSpec((1, m), lambda i: (0, 0)),          # rho out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_local, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
        ],
        interpret=interpret,
    )(cols, csq, idx, alpha, w)
    return alpha_out, rho
