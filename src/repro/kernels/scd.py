"""Pallas TPU kernel for the CoCoA local SCD solver.

This is the TPU-native analogue of the paper's "offload the hot loop to
an optimized C++ module": the H sequential coordinate-descent steps run
entirely out of VMEM, with the per-step column data streamed
HBM -> VMEM by the Pallas pipeline.

TPU adaptation (vs the CPU/C++ original):
  * SCD gathers one column c_j per step. Random-access gathers from HBM
    inside a TPU kernel would serialize on DMA latency, so the caller
    pre-gathers the H visited columns into a dense (H, m) matrix with a
    single XLA gather; the kernel then *streams* that matrix through
    VMEM in (h_blk, S, m_blk) tiles via BlockSpec — sequential-friendly
    DMA, double-buffered by the Pallas pipeline.
  * The m dimension is LANE-TILED: rho and each streamed column live as
    (S, m_blk) = (ceil(m/128), 128) 2-D tiles instead of a single
    (1, m) row. A (1, m) row occupies one sublane of every (8, 128)
    f32 register tile — 7/8 of the VPU issue width wasted; the (S, 128)
    layout packs m across sublanes so the per-step dot and the rho
    update run at full width. rho is the kernel's resident VMEM f32
    accumulator (constant index_map), exactly the paper's "persistent
    local memory" idea pushed down the memory hierarchy.
  * The per-step scalars — sigma*||c_j||^2, 1/denom and the soft-
    threshold level lam_l1/denom — are precomputed VECTORIZED outside
    the kernel and streamed as (h_blk, 1) columns, so the serial
    H-step loop carries no divides, only mul/add and the reduction.
  * ``h_blk`` is picked from a VMEM budget (``_auto_h_blk``) when not
    given: the double-buffered column stream is the dominant tenant, so
    h_blk ~ budget / (2 * S * 128 * 4), clamped to [8, 512].
  * H is padded to a multiple of h_blk with csq = 0 tail steps — exact
    no-ops by construction (the ``scsq > 0`` guard restores alpha and
    the zero column leaves rho untouched), replacing the former hard
    ``H % h_blk == 0`` requirement.

The grid is sequential on TPU, which the carried-in-VMEM state relies
on. Runs compiled on TPU and in interpret mode everywhere else (same
``compat.default_interpret`` convention as the quantize/decode
kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.utils import compat

_LANE = 128   # TPU lane width: m is tiled to (S, _LANE)
_VMEM_BUDGET = 4 * 1024 * 1024  # bytes allotted to the column stream


def _auto_h_blk(S: int) -> int:
    """Steps per grid block from the VMEM budget: the double-buffered
    f32 column stream (2 * h_blk * S * 128 * 4 bytes) is the dominant
    tenant; clamp to [8, 512] and round down to a sublane multiple."""
    h = _VMEM_BUDGET // (2 * S * _LANE * 4)
    return max(8, min(512, (h // 8) * 8))


def _scd_kernel(sigma: float, h_blk: int, cols_ref, scsq_ref, dinv_ref,
                thr_ref, idx_ref, alpha_in_ref, w_ref,
                alpha_ref, rho_ref):
    """One grid step: h_blk sequential SCD updates on the VMEM state."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        alpha_ref[...] = alpha_in_ref[...]
        rho_ref[...] = w_ref[...].astype(jnp.float32)

    def body(s, _):
        j = idx_ref[s, 0]
        c = cols_ref[s, :, :].astype(jnp.float32)       # (S, m_blk)
        scsq = scsq_ref[s, 0]                           # sigma*||c_j||^2
        a = alpha_ref[j, 0]
        rho = rho_ref[...]                              # (S, m_blk)
        z_tilde = (scsq * a - jnp.sum(rho * c)) * dinv_ref[s, 0]
        z = jnp.sign(z_tilde) * jnp.maximum(
            jnp.abs(z_tilde) - thr_ref[s, 0], 0.0)
        z = jnp.where(scsq > 0, z, a)                   # padded/zero col
        alpha_ref[j, 0] = z
        rho_ref[...] = rho + (sigma * (z - a)) * c
        return 0

    lax.fori_loop(0, h_blk, body, 0)


@functools.partial(jax.jit, static_argnames=("sigma", "lam_eta", "lam_l1",
                                             "h_blk", "interpret"))
def scd_pallas(cols: jax.Array, csq: jax.Array, idx: jax.Array,
               alpha: jax.Array, w: jax.Array, *, sigma: float,
               lam_eta: float, lam_l1: float, h_blk: int | None = None,
               interpret: bool | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """Run H = cols.shape[0] SCD steps (any H >= 1; the tail is padded
    with exact no-op steps).

    Args:
      cols:  (H, m) pre-gathered columns, streaming dtype (f32/bf16).
      csq:   (H,) squared norms of the gathered columns.
      idx:   (H,) int32 local coordinate index per step.
      alpha: (n_local,) f32 local coordinates.
      w:     (m,) round-start shared residual, f32.
      h_blk: steps per grid block; ``None`` picks it from the VMEM
             budget via ``_auto_h_blk``.
    Returns:
      (alpha_new (n_local,) f32, rho (m,) f32).
    """
    interpret = compat.default_interpret(interpret)
    H, m = cols.shape
    assert H >= 1, H
    n_local = alpha.shape[0]
    S = -(-m // _LANE)
    mp = S * _LANE
    if h_blk is None:
        h_blk = _auto_h_blk(S)
    h_blk = max(1, min(h_blk, -(-H // 8) * 8))
    Hp = -(-H // h_blk) * h_blk

    cols_p = jnp.pad(cols, ((0, Hp - H), (0, mp - m)))
    cols3 = cols_p.reshape(Hp, S, _LANE)
    idx_p = jnp.pad(idx, (0, Hp - H))[:, None]
    csq_p = jnp.pad(csq.astype(jnp.float32), (0, Hp - H))
    # per-step scalars, vectorized out of the serial loop: the kernel
    # body carries no divides (padded steps hit denom = lam_eta, which
    # is 0 for pure-l1 problems -> inf/NaN, discarded by the scsq > 0
    # guard exactly like the zero-column case)
    scsq = jnp.float32(sigma) * csq_p
    dinv = 1.0 / (scsq + jnp.float32(lam_eta))
    thr = jnp.float32(lam_l1) * dinv
    w3 = jnp.pad(w.astype(jnp.float32), (0, mp - m)).reshape(S, _LANE)

    kernel = functools.partial(_scd_kernel, float(sigma), h_blk)
    alpha_out, rho = pl.pallas_call(
        kernel,
        grid=(Hp // h_blk,),
        in_specs=[
            pl.BlockSpec((h_blk, S, _LANE), lambda i: (i, 0, 0)),  # cols
            pl.BlockSpec((h_blk, 1), lambda i: (i, 0)),   # sigma*csq
            pl.BlockSpec((h_blk, 1), lambda i: (i, 0)),   # 1/denom
            pl.BlockSpec((h_blk, 1), lambda i: (i, 0)),   # threshold
            pl.BlockSpec((h_blk, 1), lambda i: (i, 0)),   # idx stream
            pl.BlockSpec((n_local, 1), lambda i: (0, 0)),  # alpha in
            pl.BlockSpec((S, _LANE), lambda i: (0, 0)),   # w (resident)
        ],
        out_specs=[
            pl.BlockSpec((n_local, 1), lambda i: (0, 0)),  # alpha out
            pl.BlockSpec((S, _LANE), lambda i: (0, 0)),   # rho accum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_local, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, _LANE), jnp.float32),
        ],
        interpret=interpret,
    )(cols3, scsq[:, None], dinv[:, None], thr[:, None], idx_p,
      alpha.astype(jnp.float32)[:, None], w3)
    return alpha_out[:, 0], rho.reshape(mp)[:m]
