"""Pallas TPU kernels: fused decode + reduce for the compressed
exchange's gather side.

The receive side of every quantizing exchange used to dequantize the
all-gathered ``(K, wire)`` payload into a ``(K, L)`` f32 stack in HBM
and then sum it — the ``f32-intermediate`` inefficiency the
``python -m repro.analysis`` linter flags cell by cell. These kernels
fuse the whole gather side into one VMEM pass: unpack, bias-shift,
scale by the per-worker f32 scale and accumulate the f32 sum (or mean)
worker by worker, so the only f32 tensor that ever exists is the
``(L,)``-sized accumulator — no K x L f32 HBM round-trip.

Layouts mirror the encode kernels in ``repro.kernels.quant``:

  * int8: (K, L) int8 payload + (K, 1) f32 scales -> (1, L) f32.
  * int4: (K, L/2) packed bytes -> (2, L/2) f32 split-half rows
    (element ``i`` pairs with ``i + ceil(L/2)``), reshaped/sliced back
    to (L,) by the wrapper.
  * int2: (K, L/4) packed bytes -> (4, L/4) f32 split-quarter rows.

Reduction-order contract: the per-worker rows are accumulated
SEQUENTIALLY in canonical worker order (k = 0..K-1) and the mean is the
sum times the f32-rounded ``1/K`` — exactly the op sequence of the
``decode_stacked_ref`` oracle in ``repro.kernels.ref`` (which is also
the off-TPU path in ``repro.comm.codec``), so kernel and oracle are
bit-identical, pinned by tests and the ``kernels`` benchmark. Each
decoded row is walled off from the accumulate add by a
``where(isfinite(row), row, 0)`` select (``_no_fma``) so the compiler
cannot contract ``acc + q*scale`` into an FMA on one path but not the
other — observed on CPU, where the contracted chain is 1 ulp off the
strict one and ``lax.optimization_barrier`` does NOT stop it (the
contraction happens inside one fused loop at codegen, below HLO). The
select is semantically free: quantized products are finite by
construction. The wrappers pad the lane dimension to 128
with zero bytes (padded codes decode to exact zeros under every
codec's biased grid... int8's zero byte IS code 0; int4/int2 padded
bytes decode to the biased code -8/-2 times the scale but are sliced
off before they can be observed), run compiled on TPU and in interpret
mode everywhere else.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128  # TPU lane width: pad the payload's wire dimension


def _pad_lanes(x: jax.Array) -> jax.Array:
    pad = -x.shape[-1] % _LANE
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x


def _no_fma(row: jax.Array) -> jax.Array:
    """Force the ``q*scale`` product to round to f32 before it reaches
    the accumulate add: routing it through a data-dependent select
    breaks the ``fadd(fmul, ..)`` pattern the backend would otherwise
    contract to an FMA (1 ulp off the strict chain, and immune to
    ``lax.optimization_barrier``, which sits above the fused-loop
    codegen where the contraction happens). ``isfinite`` is always true
    for quantized products, so the select never changes a value."""
    return jnp.where(jnp.isfinite(row), row, jnp.float32(0.0))


def _accumulate(rows, mult: float | None):
    """Sequential f32 accumulation over the K decoded rows — the ONE
    reduction-order contract shared with the jnp oracle."""
    acc = _no_fma(rows[0])
    for r in rows[1:]:
        acc = acc + _no_fma(r)
    return acc if mult is None else acc * mult


def _dec8_kernel(K: int, mult: float | None, q_ref, s_ref, out_ref):
    rows = [q_ref[k:k + 1, :].astype(jnp.float32) * s_ref[k, 0]
            for k in range(K)]
    out_ref[...] = _accumulate(rows, mult)


def _dec4_kernel(K: int, mult: float | None, p_ref, s_ref, out_ref):
    rows = []
    for k in range(K):
        p = p_ref[k:k + 1, :].astype(jnp.int32)
        q = jnp.concatenate([p & 0xF, p >> 4], axis=0) - 8   # (2, W)
        rows.append(q.astype(jnp.float32) * s_ref[k, 0])
    out_ref[...] = _accumulate(rows, mult)


def _dec2_kernel(K: int, mult: float | None, p_ref, s_ref, out_ref):
    rows = []
    for k in range(K):
        p = p_ref[k:k + 1, :].astype(jnp.int32)
        q = jnp.concatenate([p & 0x3, (p >> 2) & 0x3, (p >> 4) & 0x3,
                             (p >> 6) & 0x3], axis=0) - 2    # (4, W)
        rows.append(q.astype(jnp.float32) * s_ref[k, 0])
    out_ref[...] = _accumulate(rows, mult)


def _reduce_mult(K: int, mean: bool) -> float | None:
    """``None`` = plain sum (no trailing multiply); the mean is the sum
    times the f32-rounded 1/K, same constant the oracle uses."""
    return (1.0 / K) if mean else None


@functools.partial(jax.jit,
                   static_argnames=("length", "mean", "interpret"))
def decode_reduce_int8(q: jax.Array, scales: jax.Array, length: int, *,
                       mean: bool = True, interpret: bool | None = None
                       ) -> jax.Array:
    """Fused decode+reduce of an all-gathered int8 payload: ``(K, L)``
    int8 + ``(K,)`` scales -> the ``(L,)`` f32 sum (or mean) — bit-
    identical to ``decode_stacked_ref('int8', ...)``."""
    from repro.utils import compat
    interpret = compat.default_interpret(interpret)
    K = q.shape[0]
    x = _pad_lanes(q)
    out = pl.pallas_call(
        functools.partial(_dec8_kernel, K, _reduce_mult(K, mean)),
        out_shape=jax.ShapeDtypeStruct((1, x.shape[1]), jnp.float32),
        interpret=interpret,
    )(x, scales.reshape(K, 1).astype(jnp.float32))
    return out[0, :length]


@functools.partial(jax.jit,
                   static_argnames=("length", "mean", "interpret"))
def decode_reduce_int4(packed: jax.Array, scales: jax.Array, length: int,
                       *, mean: bool = True,
                       interpret: bool | None = None) -> jax.Array:
    """Fused decode+reduce of an all-gathered packed-int4 payload:
    ``(K, ceil(L/2))`` uint8 + ``(K,)`` scales -> the ``(L,)`` f32 sum
    (or mean) — bit-identical to ``decode_stacked_ref('int4', ...)``."""
    from repro.utils import compat
    interpret = compat.default_interpret(interpret)
    K = packed.shape[0]
    half = packed.shape[1]
    x = _pad_lanes(packed)
    out = pl.pallas_call(
        functools.partial(_dec4_kernel, K, _reduce_mult(K, mean)),
        out_shape=jax.ShapeDtypeStruct((2, x.shape[1]), jnp.float32),
        interpret=interpret,
    )(x, scales.reshape(K, 1).astype(jnp.float32))
    return out[:, :half].reshape(2 * half)[:length]


@functools.partial(jax.jit,
                   static_argnames=("length", "mean", "interpret"))
def decode_reduce_int2(packed: jax.Array, scales: jax.Array, length: int,
                       *, mean: bool = True,
                       interpret: bool | None = None) -> jax.Array:
    """Fused decode+reduce of an all-gathered packed-int2 payload:
    ``(K, ceil(L/4))`` uint8 + ``(K,)`` scales -> the ``(L,)`` f32 sum
    (or mean) — bit-identical to ``decode_stacked_ref('int2', ...)``."""
    from repro.utils import compat
    interpret = compat.default_interpret(interpret)
    K = packed.shape[0]
    quarter = packed.shape[1]
    x = _pad_lanes(packed)
    out = pl.pallas_call(
        functools.partial(_dec2_kernel, K, _reduce_mult(K, mean)),
        out_shape=jax.ShapeDtypeStruct((4, x.shape[1]), jnp.float32),
        interpret=interpret,
    )(x, scales.reshape(K, 1).astype(jnp.float32))
    return out[:, :quarter].reshape(4 * quarter)[:length]


# codec-name dispatch used by repro.comm.codec's on-TPU path
DECODE_REDUCE = {
    "int8": decode_reduce_int8,
    "int4": decode_reduce_int4,
    "int2": decode_reduce_int2,
}


def decode_mean_int8(q, scales, length, *, interpret=None):
    """``decode_reduce_int8(..., mean=True)`` — the bench-cell entry."""
    return decode_reduce_int8(q, scales, length, mean=True,
                              interpret=interpret)


def decode_mean_int4(packed, scales, length, *, interpret=None):
    """``decode_reduce_int4(..., mean=True)`` — the bench-cell entry."""
    return decode_reduce_int4(packed, scales, length, mean=True,
                              interpret=interpret)


def decode_mean_int2(packed, scales, length, *, interpret=None):
    """``decode_reduce_int2(..., mean=True)`` — the bench-cell entry."""
    return decode_reduce_int2(packed, scales, length, mean=True,
                              interpret=interpret)
