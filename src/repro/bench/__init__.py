"""Unified benchmark harness (paper §5's methodology, made repeatable).

The paper's contribution is careful *measurement* — decomposing T_tot and
tuning H against it. This package makes those measurements comparable
across commits:

  * ``registry``  — decorator-registered benchmarks (like configs/registry).
  * ``timing``    — the warmup/repeat/min measurement discipline.
  * ``schema``    — versioned, machine-readable ``BENCH_<name>.json`` results
    with an environment fingerprint.
  * ``run``       — ``python -m repro.bench.run --smoke|--quick|--full``.
  * ``compare``   — ``python -m repro.bench.compare old new --max-regression
    1.25`` exits nonzero on regression so CI can gate.

Benchmark *workloads* live in the repo-level ``benchmarks/`` directory
(they are experiment definitions, not library code); this package is the
machinery that runs them.
"""
from repro.bench.registry import BenchContext, BenchSpec, benchmark, get, names  # noqa: F401
from repro.bench.schema import SCHEMA_VERSION, BenchResult, EnvFingerprint  # noqa: F401
