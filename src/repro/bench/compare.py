"""Regression gate over BENCH_*.json results.

  python -m repro.bench.compare old.json new.json --max-regression 1.25
  python -m repro.bench.compare old_dir/ new_dir/ --max-regression 1.25

Every metric in ``timings_s`` is lower-is-better; a metric whose
new/old ratio exceeds ``--max-regression`` is a regression and the tool
exits nonzero (so CI can gate). Improvements and new metrics pass.
Sub-millisecond timings are floored at ``--min-time`` before the ratio
so dispatch jitter on trivial measurements cannot fail the gate.

``--exact-counter PREFIX`` (repeatable) additionally gates ``counters``
whose names start with PREFIX on EXACT equality — for machine-
independent modelled quantities (e.g. ``comm_bytes_per_round_`` from
the drivers/h_sweep benchmarks), where any drift means the byte
accounting changed, not that the host got slower.
"""
from __future__ import annotations

import argparse
import glob
import os
from dataclasses import dataclass

from repro.bench import schema


@dataclass(frozen=True)
class Delta:
    benchmark: str
    metric: str
    old: float
    new: float
    ratio: float
    regression: bool


def compare_results(old: schema.BenchResult, new: schema.BenchResult,
                    max_regression: float = 1.25,
                    min_time_s: float = 1e-4) -> list[Delta]:
    deltas = []
    for metric, t_old in sorted(old.timings_s.items()):
        if metric not in new.timings_s:
            continue  # dropped metric: reported by caller, not a gate
        t_new = new.timings_s[metric]
        eff_old = max(float(t_old), min_time_s)
        eff_new = max(float(t_new), min_time_s)
        ratio = eff_new / eff_old
        deltas.append(Delta(old.benchmark, metric, float(t_old), float(t_new),
                            ratio, ratio > max_regression))
    return deltas


def compare_counters(old: schema.BenchResult, new: schema.BenchResult,
                     prefixes: list[str]) -> list[Delta]:
    """Exact-equality deltas over counters matching any of ``prefixes``.
    Counters present only on one side are skipped (coverage growth and
    device-starved hosts must not fail the gate)."""
    deltas = []
    for name, c_old in sorted(old.counters.items()):
        if not any(name.startswith(p) for p in prefixes):
            continue
        if name not in new.counters:
            continue
        c_new = new.counters[name]
        deltas.append(Delta(old.benchmark, name, float(c_old), float(c_new),
                            float("nan") if not c_old
                            else float(c_new) / float(c_old),
                            float(c_new) != float(c_old)))
    return deltas


def _pair_paths(old: str, new: str) -> list[tuple[str, str]]:
    """(old, new) file pairs; dirs are matched on BENCH_*.json filename."""
    if os.path.isdir(old) != os.path.isdir(new):
        raise SystemExit("compare: both paths must be files or both dirs")
    if not os.path.isdir(old):
        return [(old, new)]
    pairs = []
    for old_path in sorted(glob.glob(os.path.join(old, "BENCH_*.json"))):
        new_path = os.path.join(new, os.path.basename(old_path))
        if os.path.exists(new_path):
            pairs.append((old_path, new_path))
        else:
            print(f"# note: {os.path.basename(old_path)} missing from {new}")
    if not pairs:
        raise SystemExit(f"compare: no matching BENCH_*.json under {old!r}")
    return pairs


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench.compare",
                                 description=__doc__)
    ap.add_argument("old", help="baseline BENCH_*.json file or directory")
    ap.add_argument("new", help="candidate BENCH_*.json file or directory")
    ap.add_argument("--max-regression", type=float, default=1.25,
                    help="fail when new/old exceeds this ratio (default 1.25)")
    ap.add_argument("--min-time", type=float, default=1e-4,
                    help="floor (seconds) applied before the ratio")
    ap.add_argument("--exact-counter", action="append", default=[],
                    metavar="PREFIX",
                    help="gate counters starting with PREFIX on exact "
                         "equality (repeatable)")
    args = ap.parse_args(argv)

    regressions = 0
    for old_path, new_path in _pair_paths(args.old, args.new):
        old, new = schema.load(old_path), schema.load(new_path)
        if old.benchmark != new.benchmark:
            raise SystemExit(f"compare: {old_path} is {old.benchmark!r} but "
                             f"{new_path} is {new.benchmark!r}")
        if old.tier != new.tier:
            print(f"# warning: comparing tiers {old.tier!r} vs {new.tier!r} "
                  f"for {old.benchmark}")
        if old.env.device_kind != new.env.device_kind:
            print(f"# warning: device {old.env.device_kind!r} vs "
                  f"{new.env.device_kind!r} — timings may not be comparable")
        dropped = sorted(set(old.timings_s) - set(new.timings_s))
        if dropped:
            print(f"# warning: {old.benchmark}: metrics dropped in new "
                  f"result: {dropped}")
        for d in compare_results(old, new, args.max_regression, args.min_time):
            verdict = "REGRESSION" if d.regression else (
                "improved" if d.ratio < 1.0 else "ok")
            print(f"{d.benchmark:<12s} {d.metric:<36s} "
                  f"{d.old:10.5f}s -> {d.new:10.5f}s  x{d.ratio:5.2f}  {verdict}")
            regressions += d.regression
        for d in compare_counters(old, new, args.exact_counter):
            verdict = "MISMATCH" if d.regression else "exact"
            print(f"{d.benchmark:<12s} {d.metric:<36s} "
                  f"{d.old:12.0f}  -> {d.new:12.0f}   {verdict}")
            regressions += d.regression
    if regressions:
        print(f"# {regressions} regression(s) beyond "
              f"x{args.max_regression:.2f} — failing")
        return 1
    print("# no regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
