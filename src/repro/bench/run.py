"""Tiered benchmark runner.

  python -m repro.bench.run --smoke            # seconds, CI gate
  python -m repro.bench.run --quick            # minutes, dev loop
  python -m repro.bench.run --full             # the paper figures
  python -m repro.bench.run --smoke --only kernels,drivers --out results/

Emits one schema-valid ``BENCH_<name>.json`` per registered benchmark.
The smoke tier fakes a multi-device CPU host (``XLA_FLAGS=
--xla_force_host_platform_device_count=<N>``) so the sharded CoCoA driver
exercises a real mesh; this only works when jax has not been imported
yet, i.e. when invoked as ``python -m repro.bench.run``.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time
from contextlib import contextmanager

from repro.bench import registry, schema

# Per-benchmark wall-clock budget (seconds) by tier; --timeout overrides.
# The smoke budget is sized to the drivers benchmark's 24-cell
# (algorithm x scheme x mode) matrix — ~60s locally, with CI headroom.
DEFAULT_TIMEOUT_S = {"smoke": 180.0, "quick": 600.0, "full": 3600.0}


class BenchTimeout(Exception):
    pass


@contextmanager
def _time_limit(seconds: float | None):
    """SIGALRM-based soft wall-clock limit (main thread, POSIX only)."""
    if not seconds or not hasattr(signal, "SIGALRM"):
        yield
        return

    def handler(signum, frame):
        raise BenchTimeout()

    old = signal.signal(signal.SIGALRM, handler)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)


def run_one(spec: registry.BenchSpec, ctx: registry.BenchContext,
            timeout_s: float | None = None) -> schema.BenchResult:
    """Run one registered benchmark, wrapping its dict into a BenchResult."""
    env = schema.EnvFingerprint.capture()
    t0 = time.perf_counter()
    try:
        with _time_limit(timeout_s):
            out = spec.fn(ctx) or {}
        status = out.get("status", "ok")
    except BenchTimeout:
        out, status = {"notes": [f"timed out after {timeout_s:.0f}s"]}, "timeout"
    except Exception as e:  # noqa: BLE001 — one bad benchmark must not kill the run
        out, status = {"notes": [f"{type(e).__name__}: {e}"]}, "error"
    return schema.BenchResult(
        benchmark=spec.name,
        tier=ctx.tier,
        env=env,
        status=status,
        wall_s=round(time.perf_counter() - t0, 3),
        params=out.get("params", {}),
        timings_s=out.get("timings_s", {}),
        counters=out.get("counters", {}),
        rows=out.get("rows", []),
        notes=out.get("notes", []),
    )


def run_benchmarks(tier: str = "quick", only: list[str] | None = None,
                   out_dir: str = ".", seed: int = 0,
                   repeats: int | None = None,
                   timeout_s: float | None = None,
                   verbose: bool = True) -> list[schema.BenchResult]:
    """API entry point (used by tests and the CLI). Returns all results
    and writes one BENCH_<name>.json per benchmark into ``out_dir``."""
    registry.load_default_benchmarks()
    selected = [registry.get(n) for n in only] if only else [
        s for s in registry.specs() if tier in s.tiers]
    budget = timeout_s if timeout_s is not None else DEFAULT_TIMEOUT_S[tier]
    results = []
    for spec in selected:
        ctx = registry.BenchContext(tier=tier, seed=seed, repeats=repeats,
                                    timeout_s=budget, out_dir=out_dir)
        res = run_one(spec, ctx, timeout_s=budget)
        problems = schema.validate(res.to_dict())
        if problems:  # a registered benchmark emitted junk — surface it
            res.status = "error"
            res.notes.append("schema: " + "; ".join(problems))
        path = res.write(out_dir)
        results.append(res)
        if verbose:
            gates = ", ".join(f"{k}={v:.4g}s"
                              for k, v in sorted(res.timings_s.items())[:3])
            print(f"[{res.status:>7s}] {spec.name:<12s} {res.wall_s:7.1f}s"
                  f"  -> {path}" + (f"  ({gates}{', ...' if len(res.timings_s) > 3 else ''})" if gates else ""))
    return results


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.bench.run", description=__doc__)
    tier_g = ap.add_mutually_exclusive_group()
    tier_g.add_argument("--smoke", action="store_const", const="smoke",
                        dest="tier", help="seconds; deterministic CI gate")
    tier_g.add_argument("--quick", action="store_const", const="quick",
                        dest="tier", help="minutes; dev loop")
    tier_g.add_argument("--full", action="store_const", const="full",
                        dest="tier", help="the paper figures")
    tier_g.add_argument("--tier", choices=registry.TIERS, dest="tier")
    ap.set_defaults(tier="quick")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--out", type=str, default=".",
                    help="directory for BENCH_*.json (default: cwd)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repetitions override")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-benchmark wall budget in seconds")
    ap.add_argument("--devices", type=int, default=None,
                    help="fake CPU device count for the sharded driver "
                         "(default: 4 in --smoke, off otherwise)")
    ap.add_argument("--list", action="store_true",
                    help="list registered benchmarks and exit")
    args = ap.parse_args(argv)

    devices = args.devices if args.devices is not None else (
        4 if args.tier == "smoke" else 0)
    if devices and devices > 1:
        if "jax" in sys.modules:
            print("# warning: jax already imported; cannot force "
                  f"{devices} host devices", file=sys.stderr)
        else:
            import os
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "") +
                f" --xla_force_host_platform_device_count={devices}").strip()

    if args.list:
        registry.load_default_benchmarks()
        for s in registry.specs():
            fig = f" [{s.figures}]" if s.figures else ""
            print(f"{s.name:<12s}{fig} {s.description}")
        return 0

    only = args.only.split(",") if args.only else None
    t0 = time.perf_counter()
    try:
        results = run_benchmarks(tier=args.tier, only=only, out_dir=args.out,
                                 seed=args.seed, repeats=args.repeats,
                                 timeout_s=args.timeout)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    bad = [r for r in results if r.status in ("error", "timeout")]
    print(f"# {len(results)} benchmarks, tier={args.tier}, "
          f"{time.perf_counter() - t0:.1f}s total"
          + (f", {len(bad)} FAILED: {[r.benchmark for r in bad]}" if bad else ""))
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
