"""Benchmark registry: ``@benchmark("name")`` -> callable(ctx) -> result dict.

Mirrors the ``configs/registry.py`` idiom: a module-level table plus a
loader that imports the canonical benchmark modules (which self-register
on import). A registered benchmark is a function taking a
:class:`BenchContext` and returning a plain dict with any of the keys

  ``params``     dict of workload parameters (m, n, K, H grid, ...)
  ``timings_s``  dict[str, float] of wall times in seconds — these are
                 what ``repro.bench.compare`` gates on (lower is better)
  ``counters``   dict[str, float|int] of informational scalars
                 (rounds_to_eps, communicated bytes, FLOP rates, ...)
  ``rows``       list[dict] — the full per-point table (the old CSV body)
  ``notes``      list[str] — paper-claim checks and caveats
  ``status``     "ok" (default) | "skipped"
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Callable

TIERS = ("smoke", "quick", "full")

# Canonical benchmark modules; importing each registers its benchmarks.
# Kept in repo-root ``benchmarks/`` (a namespace package importable from
# the repo checkout) because they are experiment definitions.
DEFAULT_MODULES = (
    "benchmarks.bench_overheads",
    "benchmarks.bench_h_sweep",
    "benchmarks.bench_convergence",
    "benchmarks.bench_kernels",
    "benchmarks.bench_roofline",
    "benchmarks.bench_scaling",
    "benchmarks.bench_drivers",
)


@dataclass(frozen=True)
class BenchContext:
    """Everything a registered benchmark may depend on at run time."""
    tier: str = "quick"             # smoke | quick | full
    seed: int = 0
    repeats: int | None = None      # timing reps override (None = tier default)
    timeout_s: float | None = None  # enforced by the runner, advisory here
    out_dir: str = "."

    def __post_init__(self):
        if self.tier not in TIERS:
            raise ValueError(f"unknown tier {self.tier!r}; known: {TIERS}")


@dataclass(frozen=True)
class BenchSpec:
    name: str
    fn: Callable[[BenchContext], dict]
    figures: str = ""               # which paper figure(s) this reproduces
    description: str = ""
    tiers: tuple = TIERS            # tiers in which the runner includes it


_REGISTRY: dict[str, BenchSpec] = {}


def benchmark(name: str, *, figures: str = "", description: str = "",
              tiers: tuple = TIERS) -> Callable:
    """Decorator: register ``fn`` under ``name``. Re-registering the same
    name with a different function is an error (duplicate definitions);
    re-importing the same module is idempotent."""
    def deco(fn: Callable[[BenchContext], dict]):
        prev = _REGISTRY.get(name)
        if prev is not None and ((prev.fn.__module__, prev.fn.__qualname__)
                                 != (fn.__module__, fn.__qualname__)):
            raise ValueError(f"benchmark {name!r} already registered "
                             f"({prev.fn.__module__}.{prev.fn.__qualname__})")
        doc = (fn.__doc__ or "").strip()
        desc = description or (doc.splitlines()[0] if doc else "")
        _REGISTRY[name] = BenchSpec(name=name, fn=fn, figures=figures,
                                    description=desc, tiers=tuple(tiers))
        return fn
    return deco


def get(name: str) -> BenchSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown benchmark {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return list(_REGISTRY)


def specs() -> list[BenchSpec]:
    return list(_REGISTRY.values())


def load_default_benchmarks() -> list[str]:
    """Import the canonical benchmark modules (registering them).
    Returns the list of registered names. Requires the repo root on
    ``sys.path`` (true for ``python -m`` from a checkout)."""
    import sys

    errors = []
    for mod in DEFAULT_MODULES:
        try:
            importlib.import_module(mod)
        except ImportError as e:  # pragma: no cover - depends on cwd
            errors.append(f"{mod}: {e}")
    if errors and not _REGISTRY:
        raise ImportError(
            "could not import any benchmark modules — run from the repo "
            "root (the `benchmarks/` directory must be importable):\n  "
            + "\n  ".join(errors))
    for err in errors:  # partial failure must not silently shrink the gate
        print(f"# warning: benchmark module failed to import: {err}",
              file=sys.stderr)
    return names()
