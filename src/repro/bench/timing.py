"""The measurement discipline every benchmark shares: warmup, repeat, min
— plus the link calibration that turns communicated bytes into seconds.

Moved here from ``core/tradeoff.py`` so the whole harness (kernel
microbenches, solver rounds, master step, link ping-pong) times things
the same way: jit/compile excluded by warmup calls, dispatch noise
suppressed by taking the best of ``reps`` repetitions, async jax work
flushed with ``block_until_ready`` inside the timed region.

:func:`calibrate_link` measures the (bandwidth, latency) of the actual
collective a :class:`~repro.core.distributed.CommScheme` uses on the
current mesh; the resulting :class:`LinkCalibration` feeds
``core.tradeoff.TimeModel`` so the H-autotuner charges each scheme its
real wall-clock traffic (paper §5.5, Figs 6-7).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from dataclasses import dataclass


@dataclass(frozen=True)
class TimingPolicy:
    warmup: int = 1
    reps: int = 3
    reduce: str = "min"   # min | median | mean

    def combine(self, samples: list[float]) -> float:
        if self.reduce == "min":
            return min(samples)
        if self.reduce == "median":
            return float(statistics.median(samples))
        if self.reduce == "mean":
            return float(statistics.fmean(samples))
        raise ValueError(f"unknown reduce {self.reduce!r}")


DEFAULT_POLICY = TimingPolicy()


def time_callable(fn, *args, policy: TimingPolicy = DEFAULT_POLICY,
                  **kwargs) -> float:
    """Wall seconds per call of ``fn(*args, **kwargs)`` under ``policy``.
    Blocks on the result so async jax dispatch is charged to the call."""
    import jax

    for _ in range(max(policy.warmup, 0)):
        jax.block_until_ready(fn(*args, **kwargs))
    samples = []
    for _ in range(max(policy.reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        samples.append(time.perf_counter() - t0)
    return policy.combine(samples)


def measure_solver_time(trainer, H: int, reps: int = 3,
                        warmup: int = 1) -> float:
    """Wall time of one (jitted) local-solver round at the given H —
    plays the role of the paper's measured T_worker per round.

    Works for any trainer on the unified driver layer (CoCoA, mini-batch
    SCD, mini-batch SGD): the trainer is re-instantiated at ``H`` via
    its ``with_H`` clone and its virtual round is timed on fresh state.
    """
    import jax

    t = trainer.with_H(int(H))
    local, shared = t.init_state()
    return time_callable(t._round_fn, local, shared, jax.random.key(0),
                         policy=TimingPolicy(warmup=warmup, reps=reps))


# ---------------------------------------------------------------------------
# link calibration: bytes -> seconds
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LinkCalibration:
    """A fitted ``t(nbytes) = hops * latency_s + nbytes /
    bandwidth_Bps`` model of one exchange's collective on one mesh.

    ``latency_s`` is the fixed cost of ONE sequential collective
    dispatch (one hop): a fused ``xla`` collective pays it once per
    exchange, an explicit ``ring`` pays it per ``ppermute`` hop on the
    critical path — the backend's ``latency_hops`` supplies the
    multiplier (``TimeModel`` threads it through), which is what makes
    a latency-bound ring favour fewer, larger exchanges in
    ``autotune_H``."""
    bandwidth_Bps: float        # bytes per second on the wire
    latency_s: float = 0.0      # fixed per-hop cost (dispatch, sync)
    source: str = "measured"    # measured | synthetic

    def __post_init__(self):
        if not self.bandwidth_Bps > 0:
            raise ValueError(f"bandwidth must be > 0, got "
                             f"{self.bandwidth_Bps!r}")
        if self.latency_s < 0:
            raise ValueError(f"latency must be >= 0, got {self.latency_s!r}")

    def seconds_for(self, nbytes: float, overlap_s: float = 0.0,
                    latency_hops: int = 1) -> float:
        """Wall seconds the transfer costs the round, paying
        ``latency_hops`` sequential per-hop latencies. ``overlap_s`` is
        compute time the exchange may hide behind (the ``stale``
        exchange mode's one-round-delayed apply): the hidden portion is
        ``min(t_wire, overlap_s)``, so a fully-hidden transfer costs 0
        and a partially-hidden one costs only the overhang."""
        t = latency_hops * self.latency_s + nbytes / self.bandwidth_Bps
        return t - min(t, max(overlap_s, 0.0))

    def scaled(self, bandwidth_mult: float) -> "LinkCalibration":
        """A synthetic what-if link with scaled bandwidth (e.g. 0.01 for
        a 100x slower interconnect) and unchanged latency."""
        return dataclasses.replace(self, bandwidth_Bps=self.bandwidth_Bps
                                   * bandwidth_mult, source="synthetic")


def synthetic_link(bandwidth_Bps: float,
                   latency_s: float = 0.0) -> LinkCalibration:
    """A deterministic calibration for tests and what-if modelling (the
    fake-bandwidth path: no collectives run, no measurement noise)."""
    return LinkCalibration(bandwidth_Bps, latency_s, source="synthetic")


# ping-pong payload lengths (f32 elements); two decades apart so the
# least-squares fit separates the latency intercept from the 1/bw slope
CALIBRATION_LENGTHS = (1 << 10, 1 << 14, 1 << 17)


def calibrate_link(exchange=None, mesh=None,
                   lengths: tuple = CALIBRATION_LENGTHS,
                   policy: TimingPolicy = TimingPolicy(warmup=2, reps=5),
                   fake_bandwidth_Bps: float | None = None,
                   fake_latency_s: float = 0.0,
                   scheme_name: str | None = None) -> LinkCalibration:
    """Measure (bandwidth, per-hop latency) of an exchange's actual
    collective on the current mesh.

    ``exchange`` is an :class:`~repro.core.distributed.ExchangeConfig`
    or spec string (``"compressed:int4/ring"``) — the scheme picks the
    collective + byte accounting and the backend segment picks the
    fabric it runs on (default ``"persistent"`` on ``xla``). The
    deprecated ``scheme_name=`` keyword folds through
    ``resolve_exchange`` with a ``ReproDeprecationWarning``; passing
    both is a hard error. (A bare scheme string as the first positional
    is still fine — every scheme name is a valid exchange spec.)

    Ping-pong: for each payload length the scheme's ``all_reduce`` is
    jitted under ``shard_map`` on ``mesh`` (default: a 1-D ``workers``
    mesh over every visible device) and timed under ``policy``; the
    scheme's own ``bytes_per_round`` provides the x-axis and a
    least-squares line through (bytes, seconds) yields
    ``1/bandwidth`` (slope) and the latency intercept. The intercept is
    divided by the backend's ``latency_hops`` so ``latency_s`` is
    PER-HOP — ``TimeModel`` multiplies it back by the hop count, so a
    ring fit and an xla fit are charged on the same footing.

    ``fake_bandwidth_Bps`` bypasses measurement entirely and returns a
    deterministic :func:`synthetic_link` — the path tests and
    single-device hosts use.
    """
    from repro.core.distributed import resolve_exchange
    from repro.comm.collectives import get_backend

    ex = resolve_exchange(exchange, comm_scheme=scheme_name,
                          owner="calibrate_link")
    if fake_bandwidth_Bps is not None:
        return synthetic_link(fake_bandwidth_Bps, fake_latency_s)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.utils import compat

    scheme, backend = ex.scheme, ex.backend
    if mesh is None:
        mesh = compat.make_mesh((len(jax.devices()),), ("workers",))
    axis = mesh.axis_names[0]
    K = mesh.devices.size

    xs, ys = [], []
    for L in lengths:
        fn = jax.jit(compat.shard_map(
            lambda u: scheme.all_reduce(u[0], axis, backend=backend)[None],
            mesh, in_specs=P(axis), out_specs=P(axis)))
        payload = jnp.ones((K, int(L)), jnp.float32)
        xs.append(scheme.bytes_per_round(int(L), K, backend=backend))
        ys.append(time_callable(fn, payload, policy=policy))
    hops = max(get_backend(backend).latency_hops(scheme.transport, K), 1)
    if K == 1 or max(xs) == min(xs):
        # a K=1 "mesh" moves zero bytes — XLA elides single-participant
        # collectives whatever the scheme's accounting says — so all
        # that is measurable is the dispatch latency; fitting a slope
        # to that noise would return a garbage "measured" bandwidth
        return LinkCalibration(bandwidth_Bps=float("inf"),
                               latency_s=max(min(ys), 0.0) / hops,
                               source="measured")
    slope, intercept = np.polyfit(np.asarray(xs, float),
                                  np.asarray(ys, float), 1)
    # dispatch jitter can produce a non-physical fit on tiny payloads;
    # clamp to a sane always-positive model instead of failing
    if slope <= 0:
        slope = max(ys) / max(xs)
    return LinkCalibration(bandwidth_Bps=1.0 / slope,
                           latency_s=max(float(intercept), 0.0) / hops,
                           source="measured")
