"""The measurement discipline every benchmark shares: warmup, repeat, min.

Moved here from ``core/tradeoff.py`` so the whole harness (kernel
microbenches, solver rounds, master step) times things the same way:
jit/compile excluded by warmup calls, dispatch noise suppressed by
taking the best of ``reps`` repetitions, async jax work flushed with
``block_until_ready`` inside the timed region.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass


@dataclass(frozen=True)
class TimingPolicy:
    warmup: int = 1
    reps: int = 3
    reduce: str = "min"   # min | median | mean

    def combine(self, samples: list[float]) -> float:
        if self.reduce == "min":
            return min(samples)
        if self.reduce == "median":
            return float(statistics.median(samples))
        if self.reduce == "mean":
            return float(statistics.fmean(samples))
        raise ValueError(f"unknown reduce {self.reduce!r}")


DEFAULT_POLICY = TimingPolicy()


def time_callable(fn, *args, policy: TimingPolicy = DEFAULT_POLICY,
                  **kwargs) -> float:
    """Wall seconds per call of ``fn(*args, **kwargs)`` under ``policy``.
    Blocks on the result so async jax dispatch is charged to the call."""
    import jax

    for _ in range(max(policy.warmup, 0)):
        jax.block_until_ready(fn(*args, **kwargs))
    samples = []
    for _ in range(max(policy.reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        samples.append(time.perf_counter() - t0)
    return policy.combine(samples)


def measure_solver_time(trainer, H: int, reps: int = 3,
                        warmup: int = 1) -> float:
    """Wall time of one (jitted) local-solver round at the given H —
    plays the role of the paper's measured T_worker per round."""
    import jax

    from repro.core.cocoa import CoCoAConfig, CoCoATrainer

    cfg = CoCoAConfig(**{**trainer.cfg.__dict__, "H": H})
    t = CoCoATrainer(cfg, trainer.A_np, trainer.b_np)
    alpha, w = t.init_state()
    key = jax.random.key(0)
    return time_callable(t._round_fn, alpha, w, key,
                         policy=TimingPolicy(warmup=warmup, reps=reps))
