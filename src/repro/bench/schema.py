"""Versioned, machine-readable benchmark results.

One ``BENCH_<name>.json`` per benchmark per run. The file is the contract
between the runner, the compare tool, and CI artifacts — bump
``SCHEMA_VERSION`` on any incompatible change and teach ``load`` the old
shape if trajectories must stay comparable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform as _platform
from dataclasses import dataclass, field
from datetime import datetime, timezone

SCHEMA_VERSION = 1

_STATUSES = ("ok", "skipped", "timeout", "error")


@dataclass(frozen=True)
class EnvFingerprint:
    """Enough environment to judge whether two results are comparable."""
    python: str
    jax: str
    numpy: str
    platform: str
    device_kind: str
    device_count: int
    cpu_count: int
    xla_flags: str = ""

    @classmethod
    def capture(cls) -> "EnvFingerprint":
        import jax
        import numpy as np
        devices = jax.devices()
        return cls(
            python=_platform.python_version(),
            jax=jax.__version__,
            numpy=np.__version__,
            platform=_platform.platform(),
            device_kind=devices[0].device_kind if devices else "none",
            device_count=len(devices),
            cpu_count=os.cpu_count() or 1,
            xla_flags=os.environ.get("XLA_FLAGS", ""),
        )


@dataclass
class BenchResult:
    benchmark: str
    tier: str
    env: EnvFingerprint
    schema_version: int = SCHEMA_VERSION
    created_utc: str = ""
    status: str = "ok"
    wall_s: float = 0.0                      # total harness wall time
    params: dict = field(default_factory=dict)
    timings_s: dict = field(default_factory=dict)   # lower-is-better gates
    counters: dict = field(default_factory=dict)    # informational scalars
    rows: list = field(default_factory=list)        # full per-point table
    notes: list = field(default_factory=list)

    def __post_init__(self):
        if not self.created_utc:
            self.created_utc = datetime.now(timezone.utc).isoformat(
                timespec="seconds")

    # -- serialization -------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def write(self, out_dir: str = ".") -> str:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, result_filename(self.benchmark))
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path


def result_filename(benchmark: str) -> str:
    return f"BENCH_{benchmark}.json"


def validate(d: dict) -> list[str]:
    """Schema check on a loaded dict; returns a list of problems."""
    problems = []
    if d.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"schema_version {d.get('schema_version')!r} "
                        f"!= {SCHEMA_VERSION}")
    for key, typ in (("benchmark", str), ("tier", str), ("status", str),
                     ("params", dict), ("timings_s", dict),
                     ("counters", dict), ("rows", list), ("notes", list),
                     ("env", dict)):
        if not isinstance(d.get(key), typ):
            problems.append(f"field {key!r} missing or not {typ.__name__}")
    if isinstance(d.get("status"), str) and d["status"] not in _STATUSES:
        problems.append(f"status {d['status']!r} not in {_STATUSES}")
    if isinstance(d.get("timings_s"), dict):
        for k, v in d["timings_s"].items():
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                problems.append(f"timings_s[{k!r}] is not a number")
    if isinstance(d.get("env"), dict):
        env_fields = {f.name for f in dataclasses.fields(EnvFingerprint)}
        missing = env_fields - set(d["env"])
        if missing:
            problems.append(f"env missing fields {sorted(missing)}")
    return problems


def load(path: str) -> BenchResult:
    """Load + validate one BENCH_*.json; raises ValueError on bad schema."""
    with open(path) as f:
        d = json.load(f)
    problems = validate(d)
    if problems:
        raise ValueError(f"{path}: invalid bench result: " + "; ".join(problems))
    env = EnvFingerprint(**{k: d["env"][k] for k in
                            (f.name for f in dataclasses.fields(EnvFingerprint))})
    known = {f.name for f in dataclasses.fields(BenchResult)} - {"env"}
    kwargs = {k: v for k, v in d.items() if k in known}
    return BenchResult(env=env, **kwargs)
