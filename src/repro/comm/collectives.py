"""Pluggable collective backends: the exchange *fabric* under the
transport x codec exchange surface.

The paper's 20x->2x story comes from swapping the framework's
communication fabric out from under an unchanged algorithm (Spark
shuffle -> MPI allreduce); Alchemist (arXiv:1806.01270) makes exactly
that swap a pluggable interface.  This module is that seam for our
stack: the driver layer (``repro.core.distributed``) composes a
transport (which exchange pattern) with a codec (what one worker's
update looks like on the wire, ``repro.comm.codec``) — and, since this
module, with a *backend* (which collective mechanics move the bytes):

  * ``xla``   the XLA collectives (``lax.psum`` / ``all_gather`` /
    ``psum_scatter``) — one fused collective per exchange, whatever the
    interconnect topology.  This is the pre-backend behavior, verbatim:
    the refactor moved the ``lax.*`` call sites here without changing a
    single emitted op, so trajectories, HLO and byte counters are
    bit-identical to the pre-backend layer.
  * ``ring``  an explicit ``lax.ppermute`` ring: every exchange is
    decomposed into K-1 neighbour-to-neighbour hops (reduce-scatter +
    all-gather rings for the sum transports, a gather ring for the
    collected transports).  Under a ``compressed`` transport the hops
    move the *codec-encoded* wire tuple — quantized payloads ship
    hop-by-hop in their wire dtype instead of dequantizing into one
    fused all-gather — and gathers assemble parts in canonical worker
    order, so a compressed ring decodes + sums the exact same stacked
    array as the fused path (bit-identical aggregate; the sum
    transports differ from ``psum`` only in float reduction order).

Every backend also owns the *cost model* of its mechanics:

  * :meth:`CollectiveBackend.wire_bytes` — modelled bytes on the wire
    per round for a (transport, codec) exchange, asserted exactly equal
    to the bytes derived from the compiled HLO by the ``drivers``
    benchmark (collective operands for ``xla``, ``collective-permute``
    operands x K for ``ring``).
  * :meth:`CollectiveBackend.latency_hops` — how many sequential
    per-hop latencies one exchange pays: 1 for a fused ``xla``
    collective, ``2*(K-1)`` for the ring's RS+AG phases (``K-1`` for a
    single gather ring).  ``TimeModel`` charges
    ``hops * link.latency_s + bytes / bandwidth``, which is what shifts
    ``autotune_H`` toward more local work on a latency-bound ring.

The *virtual* (vmap) driver is backend-oblivious by construction — it
sums stacked per-worker updates on one host with no collectives — so a
backend changes only how the sharded/multi-process exchange moves
bytes, never the mathematical contract between the two drivers.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax.numpy as jnp
from jax import lax

from repro.comm.codec import UpdateCodec

FP_ITEMSIZE = 4        # every dense array in the system is float32

COLLECTIVE_BACKENDS = ("xla", "ring")


def padded_len(length: int, K: int) -> int:
    """The K-padded vector length every reduce-scatter-style exchange
    operates on: ``length`` rounded up to a multiple of ``K``.  The ONE
    place the padding is computed — the collectives pad/truncate with
    it and the byte models charge it, so the two can never recompute
    (and disagree on) the pad amount."""
    return -(length // -K) * K


@runtime_checkable
class CollectiveBackend(Protocol):
    """One collective fabric: the primitive collectives the exchange
    transports compose, plus the matching byte/latency cost model.

    ``all_gather`` must stack per-rank values in canonical worker order
    (slot ``j`` holds rank ``j``'s value) so transports that decode +
    sum gathered parts are numerically backend-independent.
    """

    name: str

    def all_reduce(self, x, axis: str):
        """Sum the per-rank 1-D f32 vector across the mesh axis."""
        ...

    def all_gather(self, x, axis: str):
        """Stack per-rank values along a new leading axis, canonical
        worker order: result ``(K, ...)`` with slot ``j`` = rank ``j``."""
        ...

    def reduce_scatter_gather(self, x, axis: str):
        """All-reduce decomposed as reduce-scatter + all-gather of the
        K-padded vector (each rank owns one reduced segment in
        between); returns the summed vector truncated to ``len(x)``."""
        ...

    def wire_bytes(self, transport: str, codec: UpdateCodec,
                   update_len: int, K: int, *, local_state_len: int = 0,
                   K_live: int | None = None) -> int:
        """Modelled bytes on the wire per round for one (transport,
        codec) exchange on this fabric (HLO-verified by the ``drivers``
        benchmark)."""
        ...

    def latency_hops(self, transport: str, K: int) -> int:
        """Sequential per-hop latencies one exchange pays (the
        multiplier on ``LinkCalibration.latency_s`` in ``TimeModel``)."""
        ...


# ---------------------------------------------------------------------------
# xla: the fused XLA collectives (the pre-backend behavior, verbatim)
# ---------------------------------------------------------------------------
class XLABackend:
    """``lax.psum`` / ``lax.all_gather`` / ``lax.psum_scatter`` — one
    fused collective per exchange.  Bit-identical (ops, trajectories,
    modelled bytes) to the pre-backend driver layer."""

    name = "xla"

    def all_reduce(self, x, axis: str):
        return lax.psum(x, axis)

    def all_gather(self, x, axis: str):
        return lax.all_gather(x, axis)

    def reduce_scatter_gather(self, x, axis: str):
        # explicit ring decomposition: reduce-scatter the (padded)
        # vector so each rank owns one reduced L/K segment, then
        # all-gather the segments back. lax.psum(1, axis) folds to the
        # static axis size, so the pad amount is concrete.
        L = x.shape[0]
        K = lax.psum(1, axis)
        Lp = padded_len(L, K)
        if Lp != L:
            x = jnp.concatenate([x, jnp.zeros((Lp - L,), x.dtype)])
        seg = lax.psum_scatter(x, axis, tiled=True)
        gathered = lax.all_gather(seg, axis, tiled=True)
        # the truncation is asserted against the SAME padded_len the
        # byte model charges — recomputing the pad at a call site (the
        # old drivers did) can never silently drift again
        assert gathered.shape[0] == Lp, (gathered.shape, Lp)
        return gathered[:L]

    def wire_bytes(self, transport: str, codec: UpdateCodec,
                   update_len: int, K: int, *, local_state_len: int = 0,
                   K_live: int | None = None) -> int:
        """Master-centric transports: K workers send their codec-encoded
        update up and receive the aggregate back — ``codec.wire_bytes``
        per worker each way; ``spark_faithful`` additionally ships the
        ``local_state_len`` total elements of per-worker persistent
        state up and down in f32.  ``reduce_scatter`` has no master:
        each worker moves (K-1)/K of the K-padded update each way on
        the ring — ``2*(K-1)*padded_len*4`` bytes in total.

        ``K_live`` (elastic membership) scales the master-centric
        volume by the live-worker count (a dropped worker ships
        nothing); the ``reduce_scatter`` ring is membership-oblivious.
        ``None`` means all K live — the pre-elastic formula verbatim.
        """
        if transport == "reduce_scatter":
            return 2 * (K - 1) * padded_len(update_len, K) * FP_ITEMSIZE
        persistent = transport != "spark_faithful"
        if K_live is None:
            return (2 * K * codec.wire_bytes(update_len)
                    + (0 if persistent
                       else 2 * local_state_len * FP_ITEMSIZE))
        v = 2 * K_live * codec.wire_bytes(update_len)
        a = (0 if persistent
             else 2 * (local_state_len // K) * K_live * FP_ITEMSIZE)
        return v + a

    def latency_hops(self, transport: str, K: int) -> int:
        """One fused collective = one latency, whatever the transport
        (``spark_faithful``'s state round trip rides the same dispatch)."""
        return 1


# ---------------------------------------------------------------------------
# ring: explicit lax.ppermute neighbour hops
# ---------------------------------------------------------------------------
def _ring_perm(K: int) -> list[tuple[int, int]]:
    """The one-step forward rotation every ring hop uses: rank ``i``
    sends to ``i+1 (mod K)``."""
    return [(i, (i + 1) % K) for i in range(K)]


class RingBackend:
    """Explicit ``lax.ppermute`` ring collectives.

    Gathers fill a canonical ``(K, ...)`` buffer — hop ``h`` delivers
    the part originating at rank ``idx - h (mod K)`` — so transports
    that decode + sum gathered parts (``compressed``,
    ``spark_faithful``) produce bit-identical aggregates to the fused
    path; the sum transports reduce in ring order and differ from
    ``psum`` only in float rounding.  Every hop is a real
    ``collective-permute`` in the HLO, which is how the ``drivers``
    benchmark derives (and pins) this backend's byte model.
    """

    name = "ring"

    def _gather(self, x, axis: str):
        """Canonical-order ring all-gather: ``(K,) + x.shape``."""
        K = lax.psum(1, axis)               # folds to the static size
        idx = lax.axis_index(axis)
        buf = jnp.zeros((K,) + x.shape, x.dtype)
        buf = lax.dynamic_update_index_in_dim(buf, x, idx, 0)
        cur = x
        for h in range(1, K):
            cur = lax.ppermute(cur, axis, _ring_perm(K))
            buf = lax.dynamic_update_index_in_dim(buf, cur,
                                                  (idx - h) % K, 0)
        return buf

    def all_gather(self, x, axis: str):
        return self._gather(x, axis)

    def all_reduce(self, x, axis: str):
        return self.reduce_scatter_gather(x, axis)

    def reduce_scatter_gather(self, x, axis: str):
        """The classic ring all-reduce: K-1 reduce-scatter hops (each
        rank ends owning the fully-reduced segment matching its index),
        then K-1 all-gather hops reassembling the segments in canonical
        order."""
        L = x.shape[0]
        K = lax.psum(1, axis)
        if K == 1:
            return x
        Lp = padded_len(L, K)
        if Lp != L:
            x = jnp.concatenate([x, jnp.zeros((Lp - L,), x.dtype)])
        segs = x.reshape(K, Lp // K)
        idx = lax.axis_index(axis)
        # reduce-scatter ring: rank i starts with its own contribution
        # to segment (i-1) mod K; each hop forwards the partial sum and
        # adds the local contribution to the segment just received —
        # after K-1 hops rank i holds the full sum of segment i
        acc = lax.dynamic_index_in_dim(segs, (idx - 1) % K, 0,
                                       keepdims=False)
        for h in range(1, K):
            acc = lax.ppermute(acc, axis, _ring_perm(K))
            acc = acc + lax.dynamic_index_in_dim(segs, (idx - 1 - h) % K,
                                                 0, keepdims=False)
        gathered = self._gather(acc, axis).reshape(Lp)
        # same single padding contract as the xla backend: truncation
        # is asserted against the padded_len the byte model charges
        assert gathered.shape[0] == Lp, (gathered.shape, Lp)
        return gathered[:L]

    def wire_bytes(self, transport: str, codec: UpdateCodec,
                   update_len: int, K: int, *, local_state_len: int = 0,
                   K_live: int | None = None) -> int:
        """Ring traffic: every hop, every rank forwards one part.

        * sum transports (``persistent``, ``reduce_scatter``): K-1
          reduce-scatter hops + K-1 all-gather hops of one
          ``padded_len/K`` f32 segment per rank —
          ``2*(K-1)*padded_len*4`` bytes in total (the same ring volume
          the fused ``reduce_scatter`` transport moves).
        * ``compressed``: one gather ring of the codec-encoded wire
          tuple — K ranks x (K-1) hops x ``codec.wire_bytes`` (the
          quantized payload AND its scale travel every hop).
        * ``spark_faithful``: a full-vector update gather ring plus a
          per-worker state-block gather ring —
          ``K*(K-1)*update_len*4 + (K-1)*local_state_len*4``.

        The ring is membership-oblivious (every rank relays its
        neighbours' parts whether or not it contributed), so ``K_live``
        is ignored — like the fused ``reduce_scatter`` transport.
        """
        del K_live
        if K < 2:
            return 0    # no hops — a 1-rank ring moves nothing
        if transport == "compressed":
            return K * (K - 1) * codec.wire_bytes(update_len)
        if transport == "spark_faithful":
            return (K * (K - 1) * update_len * FP_ITEMSIZE
                    + (K - 1) * local_state_len * FP_ITEMSIZE)
        return 2 * (K - 1) * padded_len(update_len, K) * FP_ITEMSIZE

    def latency_hops(self, transport: str, K: int) -> int:
        """Sequential hops on the exchange's critical path: ``K-1`` for
        the single gather ring of ``compressed``, ``2*(K-1)`` for the
        RS+AG sum rings and for ``spark_faithful``'s two gather rings."""
        if K < 2:
            return 0
        if transport == "compressed":
            return K - 1
        return 2 * (K - 1)


BACKENDS: dict[str, CollectiveBackend] = {
    "xla": XLABackend(),
    "ring": RingBackend(),
}


def get_backend(backend=None) -> CollectiveBackend:
    """Resolve a backend name (or pass a backend object through);
    ``None`` means the default fused ``xla`` fabric."""
    if backend is None:
        return BACKENDS["xla"]
    if isinstance(backend, str):
        try:
            return BACKENDS[backend]
        except KeyError:
            raise ValueError(
                f"unknown collective backend {backend!r}; known: "
                f"{COLLECTIVE_BACKENDS}") from None
    return backend


# ---------------------------------------------------------------------------
# the exchange fabric: transport composition over a backend
# ---------------------------------------------------------------------------
def exchange_all_reduce(transport: str, codec: UpdateCodec, update,
                        axis: str, backend=None, state=None):
    """Sum one worker's 1-D update across the mesh axis under the
    transport's exchange pattern, moved by ``backend``'s collectives
    (the sharded drivers' exchange — the ONE place collective mechanics
    meet the transport x codec surface).

    ``state`` is this worker's codec-state carry (the error-feedback
    residual): when given, the encode runs through
    ``codec.encode_with_state`` and the call returns ``(total,
    new_state)`` instead of the bare aggregate — stateless codecs hand
    the zero-length placeholder straight back. Only the encode changes;
    the collectives (and therefore the HLO traffic) are identical to
    the stateless path.
    """
    be = get_backend(backend)
    if transport == "compressed":
        if state is None:
            parts = codec.encode(update)        # e.g. ((L,) int8, scale)
        else:
            parts, state = codec.encode_with_state(update, state)
        gathered = tuple(be.all_gather(p, axis) for p in parts)
        # fused decode+reduce: the quantized codecs never materialize
        # the (K, L) f32 stack (Pallas kernel on TPU, sequential oracle
        # elsewhere — see repro.kernels.dequant for the order contract)
        total = codec.decode_stacked_sum(gathered, update.shape[0])
    elif transport == "spark_faithful":
        # collected at the master and re-broadcast, not reduced
        # in-place — identity, but the traffic is real.
        total = jnp.sum(be.all_gather(update, axis), axis=0)
    elif transport == "reduce_scatter":
        total = be.reduce_scatter_gather(update, axis)
    else:
        total = be.all_reduce(update, axis)
    return total if state is None else (total, state)


def exchange_roundtrip_state(state, axis: str, backend=None):
    """``spark_faithful``'s per-worker persistent-state round trip:
    all-gather through the master, each worker re-slices its own block
    — the identity, with real collective traffic on either backend."""
    be = get_backend(backend)
    gathered = be.all_gather(state, axis)       # (K, L_local)
    return lax.dynamic_index_in_dim(gathered, lax.axis_index(axis), 0,
                                    keepdims=False)
