"""Pluggable update-codec layer for the distributed exchange.

The paper's central lever is shrinking per-round communication cost
relative to compute (§5.3-§5.5). The ``compressed`` comm scheme used to
hardcode one int8 path inside ``core/distributed.py``; this package
factors the *what travels on the wire* question out of the *which
collective moves it* question, so a ``CommScheme`` composes as
transport x codec (``"compressed:int4"``) instead of growing one
special case per compression trick.

``repro.comm.collectives`` answers the third question — which fabric
*moves* the wire bytes (fused ``xla`` collectives vs an explicit
``ppermute`` ring) — behind the pluggable ``CollectiveBackend`` axis.
"""
from repro.comm.codec import (CODECS, EFWrapper, F32Codec,  # noqa: F401
                              Int2Codec, Int4Codec, Int8Codec,
                              TopKCodec, UpdateCodec, get_codec)
from repro.comm.collectives import (BACKENDS, COLLECTIVE_BACKENDS,  # noqa: F401
                                    CollectiveBackend, RingBackend,
                                    XLABackend, get_backend, padded_len)
