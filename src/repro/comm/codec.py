"""Update codecs: what one worker's update vector looks like on the wire.

An :class:`UpdateCodec` turns a 1-D f32 update into a tuple of *wire
arrays* (``encode``), reconstructs the f32 vector from a stacked
``(K, ...)`` gather of those arrays (``decode_stacked``), and prices the
per-worker payload (``wire_bytes``). The comm schemes in
``core/distributed.py`` all-gather every wire array and sum the decoded
stack — both the vmap virtual driver and the shard_map sharded driver
call the ONE codec object, so the two execution paths cannot drift.

Three codecs:

  * ``f32``  — identity: the update travels as-is (4 bytes/element).
    No scale array, so the wire tuple is just ``(dv,)`` and the HLO
    shows a single f32 all-gather.
  * ``int8`` — absmax quantization to [-127, 127] with one f32 scale
    per worker (1 byte/element + 4). This is the quantizer that used to
    live in ``core/distributed.py`` verbatim: for any nonzero input the
    encode/decode bits are identical to the pre-codec ``compressed``
    scheme (pinned by a regression test).
  * ``int4`` — absmax quantization to [-7, 7] packed two elements per
    byte (0.5 bytes/element + 4). The grid has 15 levels across
    [-absmax, absmax] (``scale = absmax / 7.5``, i.e. steps of
    2*absmax/15), so the round-trip error bound is ``scale / 2`` —
    about 8.5x the int8 codec's scale. Packing pairs element ``i`` with
    element ``i + ceil(L/2)`` (split-half pairing): pack and unpack are
    then pure elementwise nibble ops on two contiguous halves, with no
    strided gathers — the layout a TPU kernel can fuse.

Zero is a guaranteed fixed point of every codec: the quantized grids
are symmetric and contain 0, and the scale is explicitly guarded
(``scale = 1`` when ``absmax == 0``) so an all-zero update decodes to
exact zeros by construction, not by luck of ``0 / eps`` rounding.

On TPU the int8/int4 ``encode`` dispatches to the fused Pallas
quantize+pack kernel (``repro.kernels.quant``) so absmax-scale, round,
clip and pack happen in one VMEM pass instead of materializing f32
intermediates in HBM; everywhere else it runs the jnp path below, which
doubles as the kernel's bit-exact oracle.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.utils import compat

FP_ITEMSIZE = 4        # every dense array in the system is float32
SCALE_BYTES = 4        # one f32 absmax scale per worker per round

INT8_QMAX = 127.0      # int8 grid: 255 levels across [-absmax, absmax]
INT4_QMAX = 7.0        # int4 grid: 15 levels (q in [-7, 7]; -8 unused
#                        so the grid stays symmetric and contains 0)
INT4_SCALE_DIV = 7.5   # scale = absmax/7.5 -> steps of 2*absmax/15;
#                        interior elements round to within scale/2, and
#                        the absmax element itself sits at dv/scale =
#                        7.5 exactly — round-half-even takes it to 8,
#                        the clip pulls it back to 7, and the resulting
#                        error is |7.5-7|*scale = scale/2: the clip DOES
#                        bite there, landing exactly on the bound, so
#                        the round-trip error is <= scale/2 everywhere
#                        (tight at the extreme, not slack)


@runtime_checkable
class UpdateCodec(Protocol):
    """What a codec plugs into the comm schemes and the byte model.

    ``encode``         one worker's 1-D f32 update -> tuple of wire
                       arrays (payload first; a per-worker f32 scale
                       follows when the codec has one).
    ``decode``         the wire tuple of ONE worker -> the f32 vector.
    ``decode_stacked`` the all-gathered ``(K, ...)`` wire tuple -> the
                       ``(K, L)`` f32 stack the exchange sums.
    ``wire_bytes``     per-worker payload bytes for a length-L update —
                       the number the byte model charges and the
                       ``drivers`` benchmark checks against the HLO.
    """
    name: str

    def encode(self, dv: jax.Array) -> tuple[jax.Array, ...]: ...

    def decode(self, parts, length: int) -> jax.Array: ...

    def decode_stacked(self, parts, length: int) -> jax.Array: ...

    def wire_bytes(self, length: int) -> int: ...


def _absmax_scale(dv: jax.Array, div: float, eps: float) -> jax.Array:
    """Per-vector absmax scale with the explicit zero guard: an all-zero
    input gets scale 1 (any finite value works — q is 0 everywhere), so
    ``decode(encode(0)) == 0`` exactly instead of relying on ``0 / eps``
    rounding to zero."""
    absmax = jnp.max(jnp.abs(dv))
    return jnp.where(absmax > 0, absmax / div + eps, 1.0)


def _split_halves(dv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(lo, hi) halves of the zero-padded-to-even vector: element ``i``
    pairs with element ``i + ceil(L/2)``."""
    L = dv.shape[0]
    half = -(-L // 2)
    dv = jnp.concatenate([dv, jnp.zeros((2 * half - L,), dv.dtype)])
    return dv[:half], dv[half:]


class F32Codec:
    """Identity codec: the f32 update IS the wire format."""
    name = "f32"

    def encode(self, dv: jax.Array) -> tuple[jax.Array]:
        return (dv,)

    def decode(self, parts, length: int) -> jax.Array:
        return parts[0]

    def decode_stacked(self, parts, length: int) -> jax.Array:
        return parts[0]

    def wire_bytes(self, length: int) -> int:
        return length * FP_ITEMSIZE


class Int8Codec:
    """Absmax int8 quantization with a per-worker f32 scale — byte-for-
    byte the quantizer the ``compressed`` scheme always used (the
    ``+ 1e-30`` term is kept so nonzero inputs quantize identically to
    the pre-codec implementation; the zero guard only changes the
    never-observable scale of an all-zero vector)."""
    name = "int8"

    def encode(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        if compat.on_tpu():
            from repro.kernels.quant import quantize_pack_int8
            return quantize_pack_int8(dv)
        return self.encode_ref(dv)

    def encode_ref(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        """The jnp path (and the Pallas kernel's bit-exact oracle)."""
        scale = _absmax_scale(dv, INT8_QMAX, 1e-30)
        q = jnp.clip(jnp.round(dv / scale), -INT8_QMAX,
                     INT8_QMAX).astype(jnp.int8)
        return q, scale

    def decode(self, parts, length: int) -> jax.Array:
        q, scale = parts
        return q.astype(jnp.float32) * scale

    def decode_stacked(self, parts, length: int) -> jax.Array:
        q, scale = parts                     # (K, L), (K,)
        return q.astype(jnp.float32) * scale[:, None]

    def wire_bytes(self, length: int) -> int:
        return length + SCALE_BYTES


class Int4Codec:
    """Absmax int4 quantization, two elements per byte.

    ``q = clip(round(dv / scale), -7, 7)`` with ``scale = absmax/7.5``;
    nibbles are stored biased (``q + 8`` in [1, 15]) and packed
    ``lo | hi << 4`` under split-half pairing, so pack/unpack are
    elementwise on contiguous halves. Wire cost: ``ceil(L/2)`` payload
    bytes + the 4-byte scale.
    """
    name = "int4"

    def encode(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        if compat.on_tpu():
            from repro.kernels.quant import quantize_pack_int4
            return quantize_pack_int4(dv)
        return self.encode_ref(dv)

    def encode_ref(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        """The jnp path (and the Pallas kernel's bit-exact oracle)."""
        scale = _absmax_scale(dv, INT4_SCALE_DIV, 0.0)
        lo, hi = _split_halves(dv)
        qlo = jnp.clip(jnp.round(lo / scale), -INT4_QMAX,
                       INT4_QMAX).astype(jnp.int32) + 8
        qhi = jnp.clip(jnp.round(hi / scale), -INT4_QMAX,
                       INT4_QMAX).astype(jnp.int32) + 8
        return (qlo | (qhi << 4)).astype(jnp.uint8), scale

    def _unpack(self, packed: jax.Array, length: int) -> jax.Array:
        """(..., ceil(L/2)) packed bytes -> (..., L) f32-ready int grid
        values in [-7, 7] (the padded tail nibble is sliced off)."""
        p = packed.astype(jnp.int32)
        q = jnp.concatenate([p & 0xF, p >> 4], axis=-1) - 8
        return q[..., :length].astype(jnp.float32)

    def decode(self, parts, length: int) -> jax.Array:
        packed, scale = parts
        return self._unpack(packed, length) * scale

    def decode_stacked(self, parts, length: int) -> jax.Array:
        packed, scale = parts                # (K, L2), (K,)
        return self._unpack(packed, length) * scale[:, None]

    def wire_bytes(self, length: int) -> int:
        return -(-length // 2) + SCALE_BYTES


CODECS: dict[str, UpdateCodec] = {
    c.name: c for c in (F32Codec(), Int8Codec(), Int4Codec())
}


def get_codec(name: str) -> UpdateCodec:
    """Validated codec lookup (raises on typos instead of silently
    falling back to the identity)."""
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(f"unknown update codec {name!r}; "
                         f"known: {tuple(CODECS)}") from None
