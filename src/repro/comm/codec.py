"""Update codecs: what one worker's update vector looks like on the wire.

An :class:`UpdateCodec` turns a 1-D f32 update into a tuple of *wire
arrays* (``encode``), reconstructs the f32 vector from a stacked
``(K, ...)`` gather of those arrays (``decode_stacked``), and prices the
per-worker payload (``wire_bytes``). The comm schemes in
``core/distributed.py`` all-gather every wire array and sum the decoded
stack — both the vmap virtual driver and the shard_map sharded driver
call the ONE codec object, so the two execution paths cannot drift.

Base codecs:

  * ``f32``  — identity: the update travels as-is (4 bytes/element).
    No scale array, so the wire tuple is just ``(dv,)`` and the HLO
    shows a single f32 all-gather.
  * ``int8`` — absmax quantization to [-127, 127] with one f32 scale
    per worker (1 byte/element + 4). This is the quantizer that used to
    live in ``core/distributed.py`` verbatim: for any nonzero input the
    encode/decode bits are identical to the pre-codec ``compressed``
    scheme (pinned by a regression test).
  * ``int4`` — absmax quantization to [-7, 7] packed two elements per
    byte (0.5 bytes/element + 4). The grid has 15 levels across
    [-absmax, absmax] (``scale = absmax / 7.5``, i.e. steps of
    2*absmax/15), so the round-trip error bound is ``scale / 2`` —
    about 8.5x the int8 codec's scale. Packing pairs element ``i`` with
    element ``i + ceil(L/2)`` (split-half pairing): pack and unpack are
    then pure elementwise nibble ops on two contiguous halves, with no
    strided gathers — the layout a TPU kernel can fuse.
  * ``int2`` — absmax ternary quantization to {-1, 0, 1} packed four
    elements per byte (0.25 bytes/element + 4). ``scale = absmax/1.5``
    (steps of 2*absmax/3) gives the same tight ``scale / 2`` round-trip
    bound as int4 by the same clip-at-the-extreme argument. Packing
    uses split-quarter pairing — element ``i`` with ``i + q``,
    ``i + 2q``, ``i + 3q`` for ``q = ceil(L/4)`` — so pack/unpack are
    elementwise two-bit shifts on four contiguous rows.
  * ``topk(r=..)`` — magnitude sparsification: ship the
    ``k = max(1, ceil(r * L))`` largest-magnitude entries as a
    ``(values f32, indices int32)`` pair plus one f32 threshold (the
    k-th largest magnitude — it bounds the per-element truncation
    error), ``4 * ceil(r*L) * 2 + 4`` wire bytes. The values stay f32
    on the wire: the compression is in WHICH entries ship, not their
    precision, so the wire-dtype lint expects no quantized dtypes here.

The ``ef:<base>`` wrapper adds *error feedback* (1-bit SGD, Seide et
al. 2014; EF-SGD, Karimireddy et al. 2019): it encodes
``dv + residual`` with the lossy base codec and keeps the quantization
error ``(dv + residual) - decode(encode(dv + residual))`` as per-worker
codec *state*, so every bit the grid rounds away this round re-enters
the sum next round. Biased codecs (int4's clipped extremes, top-k's
dropped tail) stop accumulating a systematic floor — the error is
delayed, not destroyed. Stateful codecs are the reason the drivers in
``core/distributed.py`` thread a codec-state slot alongside the local
state; history-free codecs carry a zero-length placeholder instead
(``StatelessCodec``).

Zero is a guaranteed fixed point of every codec: the quantized grids
are symmetric and contain 0, and the scale is explicitly guarded
(``scale = 1`` when ``absmax == 0``) so an all-zero update decodes to
exact zeros by construction, not by luck of ``0 / eps`` rounding. The
elastic ``drop:`` regime leans on this — a dropped worker's zeroed
update (and zeroed residual) contributes exact zeros through any codec.

On TPU the int8/int4/int2 ``encode`` dispatches to the fused Pallas
quantize+pack kernel (``repro.kernels.quant``) so absmax-scale, round,
clip and pack happen in one VMEM pass instead of materializing f32
intermediates in HBM; everywhere else it runs the jnp path below, which
doubles as the kernel's bit-exact oracle. The gather side is fused the
same way: ``decode_stacked_sum`` / ``decode_stacked_mean`` reduce the
all-gathered ``(K, wire)`` payload worker-by-worker — on TPU through
the fused Pallas decode+reduce kernels (``repro.kernels.dequant``),
elsewhere through the sequential-accumulation oracle — so the exchange
never materializes the ``(K, L)`` f32 stack the ``f32-intermediate``
lint rule (error severity) forbids. The reduction order is the
SEQUENTIAL canonical worker order (k = 0..K-1, mean = sum times the
f32-rounded 1/K) on both paths, which replaced the pre-PR-10
``jnp.sum(stack, axis=0)`` — same math, deterministic ulp-level
difference in the aggregate. ``topk`` encode likewise dispatches to the
fused argmax+mask select kernel (``repro.kernels.topk``) on TPU.
"""
from __future__ import annotations

import functools
import math
import re
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.utils import compat

FP_ITEMSIZE = 4        # every dense array in the system is float32
SCALE_BYTES = 4        # one f32 absmax scale per worker per round

INT8_QMAX = 127.0      # int8 grid: 255 levels across [-absmax, absmax]
INT4_QMAX = 7.0        # int4 grid: 15 levels (q in [-7, 7]; -8 unused
#                        so the grid stays symmetric and contains 0)
INT4_SCALE_DIV = 7.5   # scale = absmax/7.5 -> steps of 2*absmax/15;
#                        interior elements round to within scale/2, and
#                        the absmax element itself sits at dv/scale =
#                        7.5 exactly — round-half-even takes it to 8,
#                        the clip pulls it back to 7, and the resulting
#                        error is |7.5-7|*scale = scale/2: the clip DOES
#                        bite there, landing exactly on the bound, so
#                        the round-trip error is <= scale/2 everywhere
#                        (tight at the extreme, not slack)
INT2_QMAX = 1.0        # int2 grid: 3 levels {-1, 0, 1} (biased 2-bit
#                        codes land in [1, 3]; 0 unused, same symmetry
#                        argument as int4's unused -8)
INT2_SCALE_MUL = 2.0 / 3.0  # scale = absmax * 2/3 (i.e. absmax/1.5 ->
#                        steps of 2*absmax/3): the absmax element sits
#                        at dv/scale ~= 1.5, rounds to 2, the clip pulls
#                        it back to 1, error = scale/2 — the identical
#                        tight-at-the-extreme bound as int4. Expressed
#                        as a MULTIPLY (not /1.5) because XLA strength-
#                        reduces division by 1.5 inconsistently between
#                        the jnp oracle and Pallas interpret mode — one
#                        ulp apart — while a multiply by the f32-rounded
#                        2/3 is the same op on both paths

TOPK_DEFAULT_R = 0.01  # bare "topk" keeps 1% of the entries


@runtime_checkable
class UpdateCodec(Protocol):
    """What a codec plugs into the comm schemes and the byte model.

    ``encode``         one worker's 1-D f32 update -> tuple of wire
                       arrays (payload first; a per-worker f32 scale
                       follows when the codec has one — by convention
                       the scale is always the LAST wire part).
    ``decode``         the wire tuple of ONE worker -> the f32 vector.
    ``decode_stacked`` the all-gathered ``(K, ...)`` wire tuple -> the
                       ``(K, L)`` f32 stack (diagnostic/test surface).
    ``decode_stacked_sum`` / ``decode_stacked_mean``
                       the all-gathered wire tuple -> the ``(L,)``
                       reduced aggregate directly — the call the
                       exchanges make, fused on TPU so no ``(K, L)``
                       f32 stack is ever materialized.
    ``wire_bytes``     per-worker payload bytes for a length-L update —
                       the number the byte model charges and the
                       ``drivers`` benchmark checks against the HLO.

    Stateful codecs (``stateful = True``) additionally carry a
    per-worker state vector between rounds: ``init_state(L)`` is the
    round-0 carry and ``encode_with_state`` returns
    ``(wire parts, new state)``. Stateless codecs expose the same
    surface with a zero-length placeholder so driver plumbing never
    branches on codec identity at trace time.

    ``lossless`` marks codecs whose round-trip is exact (only ``f32``):
    the delta-only check in ``optim/local_updates.py`` and the
    ``ef:`` wrapper's no-error-to-feed-back guard both key off it
    instead of string-matching names.
    """
    name: str
    stateful: bool
    lossless: bool

    def encode(self, dv: jax.Array) -> tuple[jax.Array, ...]: ...

    def decode(self, parts, length: int) -> jax.Array: ...

    def decode_stacked(self, parts, length: int) -> jax.Array: ...

    def decode_stacked_sum(self, parts, length: int) -> jax.Array: ...

    def decode_stacked_mean(self, parts, length: int) -> jax.Array: ...

    def wire_bytes(self, length: int) -> int: ...

    def init_state(self, length: int) -> jax.Array: ...

    def encode_with_state(self, dv: jax.Array, state: jax.Array
                          ) -> tuple[tuple[jax.Array, ...], jax.Array]: ...


def _absmax_scale(dv: jax.Array, div: float, eps: float) -> jax.Array:
    """Per-vector absmax scale with the explicit zero guard: an all-zero
    input gets scale 1 (any finite value works — q is 0 everywhere), so
    ``decode(encode(0)) == 0`` exactly instead of relying on ``0 / eps``
    rounding to zero."""
    absmax = jnp.max(jnp.abs(dv))
    return jnp.where(absmax > 0, absmax / div + eps, 1.0)


def _split_halves(dv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(lo, hi) halves of the zero-padded-to-even vector: element ``i``
    pairs with element ``i + ceil(L/2)``."""
    L = dv.shape[0]
    half = -(-L // 2)
    dv = jnp.concatenate([dv, jnp.zeros((2 * half - L,), dv.dtype)])
    return dv[:half], dv[half:]


def _split_quarters(dv: jax.Array) -> jax.Array:
    """(4, ceil(L/4)) rows of the zero-padded vector: element ``i``
    pairs with ``i + q``, ``i + 2q``, ``i + 3q`` (split-quarter
    pairing), the two-bit analogue of ``_split_halves``."""
    L = dv.shape[0]
    quarter = -(-L // 4)
    dv = jnp.concatenate([dv, jnp.zeros((4 * quarter - L,), dv.dtype)])
    return dv.reshape(4, quarter)


class StatelessCodec:
    """Base for history-free codecs: the per-worker codec state is a
    zero-length placeholder and ``encode_with_state`` is ``encode`` —
    the drivers thread ONE surface regardless of codec identity.

    The base ``decode_stacked_sum`` / ``decode_stacked_mean`` reduce
    the decoded stack with ``jnp.sum`` / ``jnp.mean`` — fine for codecs
    whose stack is already f32 wire data (``f32``) or sparse scatters
    (``topk``); the quantized codecs override with the fused
    sequential-accumulation path (``_QuantFusedReduce``)."""
    stateful = False
    lossless = False

    def init_state(self, length: int) -> jax.Array:
        del length
        return jnp.zeros((0,), jnp.float32)

    def encode_with_state(self, dv: jax.Array, state: jax.Array):
        return self.encode(dv), state

    def decode_stacked_sum(self, parts, length: int) -> jax.Array:
        return jnp.sum(self.decode_stacked(parts, length), axis=0)

    def decode_stacked_mean(self, parts, length: int) -> jax.Array:
        return jnp.mean(self.decode_stacked(parts, length), axis=0)


class _QuantFusedReduce:
    """Fused decode+reduce for the quantized codecs (int8/int4/int2).

    ``decode_reduce_ref`` is the jnp oracle the Pallas kernels in
    ``repro.kernels.dequant`` are bit-identical to: decode one worker's
    row at a time and accumulate SEQUENTIALLY in canonical worker order
    — the only f32 intermediates are ``(L,)``-sized, K times smaller
    than the ``(K, L)`` stack the ``f32-intermediate`` lint rule (error
    severity) forbids, so the off-TPU sweep in ``repro.analysis`` is
    clean by the same construction that makes the TPU path fast. The
    mean is the sum times the f32-rounded ``1/K`` (bit-equal to
    ``jnp.mean`` would not survive the fused accumulation; the kernels
    and this oracle agree with EACH OTHER, which is the contract)."""

    def decode_reduce_ref(self, parts, length: int, *, mean: bool
                          ) -> jax.Array:
        from repro.kernels.dequant import _no_fma
        payload, scales = parts              # (K, wire), (K,)
        K = payload.shape[0]
        # _no_fma walls the decoded row (a q*scale product) off from
        # the accumulate add — without it the backend may FMA-contract
        # ``acc + q*scale`` on one compilation but not another, a 1-ulp
        # drift that breaks the kernel/oracle bit-identity contract.
        acc = _no_fma(self.decode((payload[0], scales[0]), length))
        for k in range(1, K):
            acc = acc + _no_fma(
                self.decode((payload[k], scales[k]), length))
        return acc * (1.0 / K) if mean else acc

    def _decode_reduce(self, parts, length: int, *, mean: bool
                       ) -> jax.Array:
        if compat.on_tpu():
            from repro.kernels.dequant import DECODE_REDUCE
            return DECODE_REDUCE[self.name](parts[0], parts[1], length,
                                            mean=mean)
        return self.decode_reduce_ref(parts, length, mean=mean)

    def decode_stacked_sum(self, parts, length: int) -> jax.Array:
        return self._decode_reduce(parts, length, mean=False)

    def decode_stacked_mean(self, parts, length: int) -> jax.Array:
        return self._decode_reduce(parts, length, mean=True)


class F32Codec(StatelessCodec):
    """Identity codec: the f32 update IS the wire format."""
    name = "f32"
    lossless = True

    def encode(self, dv: jax.Array) -> tuple[jax.Array]:
        return (dv,)

    def decode(self, parts, length: int) -> jax.Array:
        return parts[0]

    def decode_stacked(self, parts, length: int) -> jax.Array:
        return parts[0]

    def wire_bytes(self, length: int) -> int:
        return length * FP_ITEMSIZE


class Int8Codec(_QuantFusedReduce, StatelessCodec):
    """Absmax int8 quantization with a per-worker f32 scale — byte-for-
    byte the quantizer the ``compressed`` scheme always used (the
    ``+ 1e-30`` term is kept so nonzero inputs quantize identically to
    the pre-codec implementation; the zero guard only changes the
    never-observable scale of an all-zero vector)."""
    name = "int8"

    def encode(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        if compat.on_tpu():
            from repro.kernels.quant import quantize_pack_int8
            return quantize_pack_int8(dv)
        return self.encode_ref(dv)

    def encode_ref(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        """The jnp path (and the Pallas kernel's bit-exact oracle)."""
        scale = _absmax_scale(dv, INT8_QMAX, 1e-30)
        q = jnp.clip(jnp.round(dv / scale), -INT8_QMAX,
                     INT8_QMAX).astype(jnp.int8)
        return q, scale

    def decode(self, parts, length: int) -> jax.Array:
        q, scale = parts
        return q.astype(jnp.float32) * scale

    def decode_stacked(self, parts, length: int) -> jax.Array:
        q, scale = parts                     # (K, L), (K,)
        return q.astype(jnp.float32) * scale[:, None]

    def wire_bytes(self, length: int) -> int:
        return length + SCALE_BYTES


class Int4Codec(_QuantFusedReduce, StatelessCodec):
    """Absmax int4 quantization, two elements per byte.

    ``q = clip(round(dv / scale), -7, 7)`` with ``scale = absmax/7.5``;
    nibbles are stored biased (``q + 8`` in [1, 15]) and packed
    ``lo | hi << 4`` under split-half pairing, so pack/unpack are
    elementwise on contiguous halves. Wire cost: ``ceil(L/2)`` payload
    bytes + the 4-byte scale.
    """
    name = "int4"

    def encode(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        if compat.on_tpu():
            from repro.kernels.quant import quantize_pack_int4
            return quantize_pack_int4(dv)
        return self.encode_ref(dv)

    def encode_ref(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        """The jnp path (and the Pallas kernel's bit-exact oracle)."""
        scale = _absmax_scale(dv, INT4_SCALE_DIV, 0.0)
        lo, hi = _split_halves(dv)
        qlo = jnp.clip(jnp.round(lo / scale), -INT4_QMAX,
                       INT4_QMAX).astype(jnp.int32) + 8
        qhi = jnp.clip(jnp.round(hi / scale), -INT4_QMAX,
                       INT4_QMAX).astype(jnp.int32) + 8
        return (qlo | (qhi << 4)).astype(jnp.uint8), scale

    def _unpack(self, packed: jax.Array, length: int) -> jax.Array:
        """(..., ceil(L/2)) packed bytes -> (..., L) f32-ready int grid
        values in [-7, 7] (the padded tail nibble is sliced off)."""
        p = packed.astype(jnp.int32)
        q = jnp.concatenate([p & 0xF, p >> 4], axis=-1) - 8
        return q[..., :length].astype(jnp.float32)

    def decode(self, parts, length: int) -> jax.Array:
        packed, scale = parts
        return self._unpack(packed, length) * scale

    def decode_stacked(self, parts, length: int) -> jax.Array:
        packed, scale = parts                # (K, L2), (K,)
        return self._unpack(packed, length) * scale[:, None]

    def wire_bytes(self, length: int) -> int:
        return -(-length // 2) + SCALE_BYTES


class Int2Codec(_QuantFusedReduce, StatelessCodec):
    """Absmax ternary quantization, four elements per byte.

    ``q = clip(round(dv / scale), -1, 1)`` with ``scale = absmax*2/3``;
    codes are stored biased (``q + 2`` in [1, 3]) and packed
    ``q0 | q1<<2 | q2<<4 | q3<<6`` under split-quarter pairing, so
    pack/unpack are elementwise two-bit shifts on four contiguous rows.
    Wire cost: ``ceil(L/4)`` payload bytes + the 4-byte scale. Alone
    the 3-level grid is far too coarse to converge — it exists for the
    ``ef:int2`` composition, where the residual carries what the grid
    cannot.
    """
    name = "int2"

    def encode(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        if compat.on_tpu():
            from repro.kernels.quant import quantize_pack_int2
            return quantize_pack_int2(dv)
        return self.encode_ref(dv)

    def encode_ref(self, dv: jax.Array) -> tuple[jax.Array, jax.Array]:
        """The jnp path (and the Pallas kernel's bit-exact oracle)."""
        absmax = jnp.max(jnp.abs(dv))
        scale = jnp.where(absmax > 0, absmax * INT2_SCALE_MUL, 1.0)
        rows = _split_quarters(dv)
        q = jnp.clip(jnp.round(rows / scale), -INT2_QMAX,
                     INT2_QMAX).astype(jnp.int32) + 2
        packed = q[0] | (q[1] << 2) | (q[2] << 4) | (q[3] << 6)
        return packed.astype(jnp.uint8), scale

    def _unpack(self, packed: jax.Array, length: int) -> jax.Array:
        """(..., ceil(L/4)) packed bytes -> (..., L) f32-ready grid
        values in [-1, 1] (padded tail codes are sliced off)."""
        p = packed.astype(jnp.int32)
        q = jnp.concatenate([p & 0x3, (p >> 2) & 0x3, (p >> 4) & 0x3,
                             (p >> 6) & 0x3], axis=-1) - 2
        return q[..., :length].astype(jnp.float32)

    def decode(self, parts, length: int) -> jax.Array:
        packed, scale = parts
        return self._unpack(packed, length) * scale

    def decode_stacked(self, parts, length: int) -> jax.Array:
        packed, scale = parts                # (K, L4), (K,)
        return self._unpack(packed, length) * scale[:, None]

    def wire_bytes(self, length: int) -> int:
        return -(-length // 4) + SCALE_BYTES


class TopKCodec(StatelessCodec):
    """Magnitude sparsification: ship only the ``k = max(1, ceil(r*L))``
    largest-|.| entries.

    Wire tuple: ``(values f32 (k,), indices int32 (k,), threshold)``
    where the threshold — kept last like every codec's scale — is the
    k-th largest magnitude: shipped entries decode exactly, and every
    dropped entry's error is bounded by it. Wire cost:
    ``4 * ceil(r*L) * 2 + 4`` bytes (f32 value + int32 index per kept
    entry, plus the threshold). The values are legitimately f32 on the
    wire, so this codec has no ``CODEC_WIRE_DTYPE`` entry.
    """

    def __init__(self, r: float):
        self.r = float(r)
        self.name = f"topk(r={self.r:g})"

    def _k(self, length: int) -> int:
        return min(int(length), max(1, math.ceil(self.r * length)))

    def encode(self, dv: jax.Array) -> tuple[jax.Array, ...]:
        if compat.on_tpu():
            from repro.kernels.topk import topk_select
            return topk_select(dv, self._k(dv.shape[0]))
        return self.encode_ref(dv)

    def encode_ref(self, dv: jax.Array) -> tuple[jax.Array, ...]:
        """The jnp path (and the Pallas select kernel's bit-exact
        oracle): ``lax.top_k`` over the magnitudes."""
        k = self._k(dv.shape[0])
        mags, idx = jax.lax.top_k(jnp.abs(dv), k)
        return jnp.take(dv, idx), idx.astype(jnp.int32), mags[k - 1]

    def decode(self, parts, length: int) -> jax.Array:
        values, idx, thr = parts
        values = self._enforce(values, thr)
        return jnp.zeros((length,), jnp.float32).at[idx].set(values)

    def decode_stacked(self, parts, length: int) -> jax.Array:
        values, idx, thr = parts             # (K, k), (K, k), (K,)
        K = values.shape[0]
        values = self._enforce(values, thr[:, None])
        out = jnp.zeros((K, length), jnp.float32)
        return out.at[jnp.arange(K)[:, None], idx].set(values)

    @staticmethod
    def _enforce(values, thr):
        """Drop anything below the advertised threshold. Every honestly
        encoded value satisfies ``|v| >= thr`` (thr IS the k-th largest
        magnitude), so this is the identity on real wire data — but it
        makes decode actually CONSUME the threshold, so its all-gather
        is live payload instead of dead code XLA deletes (the byte
        model charges the threshold; the HLO must carry it)."""
        return jnp.where(jnp.abs(values) >= thr, values, 0.0)

    def wire_bytes(self, length: int) -> int:
        return 2 * FP_ITEMSIZE * self._k(length) + SCALE_BYTES


class EFWrapper:
    """Error feedback around a lossy base codec (``ef:<base>``).

    ``encode_with_state`` compresses ``dv + residual`` and returns the
    new residual ``(dv + residual) - decode(...)`` — the per-worker
    state the drivers carry between rounds. Everything the base grid
    rounds away (or top-k drops) re-enters the sum next round, which
    converts the base codec's bias into a bounded delay: this is what
    lifts the plain-int4 convergence floor. The plain ``encode`` entry
    point encodes with a zero residual, so stateless call sites (link
    calibration, codec-path tests) see exactly the base codec.
    """
    stateful = True
    lossless = False

    def __init__(self, base: UpdateCodec):
        self.base = base
        self.name = f"ef:{base.name}"

    def init_state(self, length: int) -> jax.Array:
        return jnp.zeros((length,), jnp.float32)

    def encode(self, dv: jax.Array) -> tuple[jax.Array, ...]:
        return self.base.encode(dv)

    def encode_ref(self, dv: jax.Array) -> tuple[jax.Array, ...]:
        return self.base.encode_ref(dv)

    def encode_with_state(self, dv: jax.Array, state: jax.Array):
        e = dv + state
        parts = self.base.encode(e)
        return parts, e - self.base.decode(parts, e.shape[0])

    def decode(self, parts, length: int) -> jax.Array:
        return self.base.decode(parts, length)

    def decode_stacked(self, parts, length: int) -> jax.Array:
        return self.base.decode_stacked(parts, length)

    def decode_stacked_sum(self, parts, length: int) -> jax.Array:
        return self.base.decode_stacked_sum(parts, length)

    def decode_stacked_mean(self, parts, length: int) -> jax.Array:
        return self.base.decode_stacked_mean(parts, length)

    def wire_bytes(self, length: int) -> int:
        return self.base.wire_bytes(length)


CODECS: dict[str, UpdateCodec] = {
    c.name: c for c in (F32Codec(), Int8Codec(), Int4Codec(), Int2Codec())
}

_TOPK_RE = re.compile(r"topk(?:\((?P<arg>[^)]*)\))?")


@functools.lru_cache(maxsize=None)
def get_codec(name: str) -> UpdateCodec:
    """Validated codec lookup (raises on typos instead of silently
    falling back to the identity). Cached, so every call site parsing
    the same spec shares ONE codec object — the vmap/shard_map identity
    contract extends to parameterized codecs like ``topk(r=..)``."""
    if name in CODECS:
        return CODECS[name]
    if name.startswith("ef:"):
        inner = name[len("ef:"):]
        if inner.startswith("ef:"):
            raise ValueError(
                f"bad codec {name!r}: error feedback does not nest — "
                f"one residual per worker; use a single 'ef:' prefix")
        base = get_codec(inner)
        if base.lossless:
            raise ValueError(
                f"bad codec {name!r}: {inner!r} round-trips exactly, so "
                f"there is no quantization error to feed back — drop "
                f"the 'ef:' prefix")
        return EFWrapper(base)
    m = _TOPK_RE.fullmatch(name)
    if m is not None:
        arg = m.group("arg")
        if not arg:
            r = TOPK_DEFAULT_R
        else:
            body = arg[2:] if arg.startswith("r=") else arg
            try:
                r = float(body)
            except ValueError:
                raise ValueError(
                    f"bad codec {name!r}: expected topk(r=<float>), "
                    f"got argument {arg!r}") from None
        if not 0.0 < r <= 1.0:
            raise ValueError(
                f"bad codec {name!r}: keep ratio r={r!r} must satisfy "
                f"0 < r <= 1")
        return TopKCodec(r)
    raise ValueError(
        f"unknown update codec {name!r}; known: {tuple(CODECS)} plus "
        f"'topk(r=<float>)' and the 'ef:<lossy base>' wrapper")
