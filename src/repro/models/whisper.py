"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the brief, the mel-spectrogram + conv feature extractor is NOT
implemented — ``input_specs`` supplies (B, source_len, d_model) frame
embeddings. This module implements the transformer: bidirectional
encoder over frames, causal decoder with cross-attention, learned
positional embeddings, LayerNorm + GELU + biases (whisper-tiny style),
tied unembedding.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab
from repro.models import layers as L

MAX_TARGET_POSITIONS = 32_768   # generous; real whisper is 448


def _sinusoid(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def _init_enc_layer(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_init(cfg.d_model, "layernorm"),
        "attn": L.init_attention(k1, cfg, dtype),
        "mlp_norm": L.norm_init(cfg.d_model, "layernorm"),
        "mlp": L.init_mlp(k2, cfg, dtype=dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": L.norm_init(cfg.d_model, "layernorm"),
        "self": L.init_attention(k1, cfg, dtype),
        "cross_norm": L.norm_init(cfg.d_model, "layernorm"),
        "cross": L.init_attention(k2, cfg, dtype),
        "mlp_norm": L.norm_init(cfg.d_model, "layernorm"),
        "mlp": L.init_mlp(k3, cfg, dtype=dtype),
    }


def init_whisper(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    v = padded_vocab(cfg)
    n_enc = cfg.encdec.num_layers
    keys = jax.random.split(key, n_enc + cfg.num_layers + 2)
    return {
        "embed": (jax.random.normal(keys[-1], (v, cfg.d_model), jnp.float32)
                  * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(
            keys[-2], (MAX_TARGET_POSITIONS, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype),
        "enc_layers": [_init_enc_layer(keys[i], cfg, dtype)
                       for i in range(n_enc)],
        "enc_norm": L.norm_init(cfg.d_model, "layernorm"),
        "dec_layers": [_init_dec_layer(keys[n_enc + i], cfg, dtype)
                       for i in range(cfg.num_layers)],
        "dec_norm": L.norm_init(cfg.d_model, "layernorm"),
    }


def _bidir_attn(p, cfg, x):
    """Non-causal encoder self-attention (dense — source_len is short)."""
    B, S, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = L.dense(p["wq"], x).reshape(B, S, H, Dh)
    k = L.dense(p["wk"], x).reshape(B, S, KV, Dh)
    v = L.dense(p["wv"], x).reshape(B, S, KV, Dh)
    out = L._attend_dense(q, k, v, None, Dh ** -0.5)
    return L.dense(p["wo"], out.reshape(B, S, H * Dh))


def encode(params, cfg: ModelConfig, frame_embeds):
    x = frame_embeds + _sinusoid(frame_embeds.shape[1],
                                 cfg.d_model).astype(frame_embeds.dtype)[None]
    for lp in params["enc_layers"]:
        x = x + _bidir_attn(lp["attn"], cfg,
                            L.apply_norm(lp["attn_norm"], x, "layernorm"))
        x = x + L.mlp_apply(lp["mlp"], cfg,
                            L.apply_norm(lp["mlp_norm"], x, "layernorm"))
    return L.apply_norm(params["enc_norm"], x, "layernorm")


def _cross_kv(p, cfg, enc_out):
    B, S, _ = enc_out.shape
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    k = L.dense(p["wk"], enc_out).reshape(B, S, KV, Dh)
    v = L.dense(p["wv"], enc_out).reshape(B, S, KV, Dh)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return k, v, pos


def decode(params, cfg: ModelConfig, tokens, enc_out, *, mode="full",
           states=None, positions=None):
    """Teacher-forced decode (mode='full') or single step (mode='step').

    states (step mode): list per layer of {"self": attn-cache,
    "cross_k","cross_v"} built by init_whisper_states + encode.
    """
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    x = params["embed"][tokens] + params["dec_pos"][positions]
    new_states = [None] * len(params["dec_layers"])

    def dec_layer(lp, x, ck, cv, cpos, self_state):
        h, self_state = L.attention_apply(
            lp["self"], cfg, L.apply_norm(lp["self_norm"], x, "layernorm"),
            positions, mode=mode, state=self_state)
        x = x + h
        h, _ = L.attention_apply(
            lp["cross"], cfg, L.apply_norm(lp["cross_norm"], x, "layernorm"),
            positions, mode=mode, state=None, cross_kv=(ck, cv, cpos))
        x = x + h
        x = x + L.mlp_apply(lp["mlp"], cfg,
                            L.apply_norm(lp["mlp_norm"], x, "layernorm"))
        return x, self_state

    if mode == "full" and tokens.shape[1] > 512:
        dec_layer = jax.checkpoint(dec_layer)   # teacher-forcing remat

    for i, lp in enumerate(params["dec_layers"]):
        st = None if states is None else states[i]
        self_state = None if st is None else st["self"]
        if st is None:
            ck, cv, cpos = _cross_kv(lp["cross"], cfg, enc_out)
        else:
            ck, cv = st["cross_k"], st["cross_v"]
            cpos = jnp.broadcast_to(
                jnp.arange(ck.shape[1], dtype=jnp.int32)[None], ck.shape[:2])
        x, self_state = dec_layer(lp, x, ck, cv, cpos, self_state)
        if st is not None:
            new_states[i] = {"self": self_state, "cross_k": ck, "cross_v": cv}
    x = L.apply_norm(params["dec_norm"], x, "layernorm")
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, new_states


def init_whisper_states(params, cfg: ModelConfig, B: int, max_len: int,
                        enc_out, dtype=jnp.bfloat16) -> list:
    states = []
    for lp in params["dec_layers"]:
        ck, cv, _ = _cross_kv(lp["cross"], cfg, enc_out)
        states.append({
            "self": L.init_attn_cache(cfg, B, max_len, dtype=dtype),
            "cross_k": ck, "cross_v": cv,
        })
    return states
