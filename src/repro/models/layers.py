"""Functional model primitives (no framework deps beyond jax).

Every module is a pair ``init_*(key, ...) -> params-dict`` and an apply
function. Blocks share the interface::

    block_apply(params, cfg, kind, x, positions, mode, state)
        -> (y, new_state)

where ``mode`` is "full" (train / prefill over a whole sequence) or
"step" (single-token decode against persistent state), and ``state`` is
the block's decode state (KV cache / ring buffer / SSM state / LRU
state). ``positions`` is (B, S) int32 absolute positions — or
(B, S, 3) for M-RoPE.

Attention over long sequences uses a blockwise (flash-style) streaming
softmax implemented with lax.scan so that no (S, S) score matrix is ever
materialized. NOTE (roofline): the blockwise form computes the full
q-chunk x kv-chunk rectangle and masks, so causal prefill does ~2x the
useful attention FLOPs; benchmarks correct for this analytically.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

# ----------------------------------------------------------------------
# logical partitioning (activation sharding constraints)
# ----------------------------------------------------------------------
# Model code is mesh-agnostic; the launcher binds logical axes ("dp" for
# batch, "tp" for tensor/feature/expert parallel) to mesh axis names
# before lowering. Unbound (CPU tests) -> constraints are no-ops.

_AXES: dict = {"dp": None, "tp": None, "mesh": None}


def set_partitioning(dp=None, tp=None, mesh=None):
    """Bind logical axes to mesh axis names (tuple allowed for dp).
    ``mesh`` enables the shard_map expert-parallel MoE path."""
    _AXES["dp"], _AXES["tp"], _AXES["mesh"] = dp, tp, mesh


def constrain(x, *logical):
    """with_sharding_constraint by logical dims ('dp'|'tp'|None)."""
    if _AXES["dp"] is None and _AXES["tp"] is None:
        return x
    from jax.sharding import PartitionSpec as P
    parts = []
    for i, l in enumerate(logical):
        if l == "dpt":  # combined data+model axes (context parallelism)
            dp = _AXES.get("dp") or ()
            dp = dp if isinstance(dp, tuple) else (dp,)
            tp = _AXES.get("tp")
            ax = tuple(a for a in (*dp, tp) if a) or None
        else:
            ax = _AXES.get(l) if isinstance(l, str) else None
        # skip axes that would shard a trivial/ill-fitting dim (e.g. the
        # B=1 long-context decode batch, or 6-head whisper attention)
        if ax is not None and (x.shape[i] == 1
                               or (x.shape[i] < 16 and x.shape[i] % 8 != 0)):
            ax = None
        parts.append(ax)
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except (ValueError, RuntimeError):
        return x  # no mesh context (e.g. stray CPU call) -> no-op


# ----------------------------------------------------------------------
# basics
# ----------------------------------------------------------------------

def _split(key, n):
    return jax.random.split(key, n)


def dense_init(key, d_in, d_out, *, bias=False, dtype=jnp.bfloat16, scale=None):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y + p.get("bias", 0.0)
    return (y * p["scale"]).astype(x.dtype)


_ACTS = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}

# ----------------------------------------------------------------------
# RoPE (full / partial / 2d / M-RoPE)
# ----------------------------------------------------------------------

def _rope_angles(positions, rot_dim, theta):
    """positions (..., S) -> cos/sin of shape (..., S, rot_dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """x (..., rot_dim) with cos/sin (..., rot_dim/2): pairwise rotation."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def apply_rope(x, positions, cfg: ModelConfig):
    """x (B,S,H,D); positions (B,S) or (B,S,3) for mrope."""
    D = x.shape[-1]
    if cfg.rope_style == "none":
        return x
    rot = int(D * (0.5 if cfg.rope_style == "2d" else cfg.rope_frac))
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    xf = xr.astype(jnp.float32)
    if cfg.rope_style == "mrope":
        # 3 position components (t, h, w) rotate disjoint sections.
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[..., None],
                                         (*positions.shape, 3))
        nsec = 3
        half = rot // 2
        sec = [half - 2 * (half // nsec), half // nsec, half // nsec]
        cs, ss = [], []
        for i in range(nsec):
            c, s = _rope_angles(positions[..., i], rot, cfg.rope_theta)
            cs.append(c)
            ss.append(s)
        # section i of the rotary pairs uses position component i
        bounds = [0, sec[0], sec[0] + sec[1], half]
        cos = jnp.concatenate(
            [cs[i][..., bounds[i]:bounds[i + 1]] for i in range(nsec)], -1)
        sin = jnp.concatenate(
            [ss[i][..., bounds[i]:bounds[i + 1]] for i in range(nsec)], -1)
        out = _rotate(xf, cos[:, :, None, :], sin[:, :, None, :])
    else:
        cos, sin = _rope_angles(positions, rot, cfg.rope_theta)  # (B,S,rot/2)
        out = _rotate(xf, cos[:, :, None, :], sin[:, :, None, :])
    return jnp.concatenate([out.astype(x.dtype), xp], -1)

# ----------------------------------------------------------------------
# blockwise (flash-style) attention — no (S,S) materialization
# ----------------------------------------------------------------------

def _attend_dense(q, k, v, mask, scale, softcap=None):
    """Reference dense attention for short S / decode. q (B,Sq,H,D),
    k/v (B,Skv,KV,D); mask broadcastable to (B,H,Sq,Skv) or None."""
    B, Sq, H, D = q.shape
    KV, Dv = k.shape[2], v.shape[-1]
    g = H // KV
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, KV, g, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    if mask is not None:
        # mask (B,1,1,Skv) or (B,1,Sq,Skv) -> broadcast over (B,KV,g,Sq,Skv)
        m = mask[:, :, None, :, :]
        logits = jnp.where(m, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


_FORCE_DENSE_ATTN = False


def set_force_dense_attention(v: bool) -> None:
    """Roofline-only switch: the flash scans are cost-counted once by
    XLA's cost analysis, so the roofline lowering uses dense attention
    (identical FLOPs/bytes semantics, fully counted). Never used for
    real execution paths."""
    global _FORCE_DENSE_ATTN
    _FORCE_DENSE_ATTN = v


def flash_attention(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                    scale, q_chunk=512, kv_chunk=1024, softcap=None):
    """Memory-efficient attention: O(S) residuals in both directions.

    Forward streams kv chunks with an online softmax; backward (custom
    VJP) RECOMPUTES the score chunks instead of saving them — without
    this, reverse-mode AD of the scan stores every (q_chunk x kv_chunk)
    probability block, i.e. the full S^2 score matrix.
    softcap is only supported on the non-differentiable path (decode)."""
    if _FORCE_DENSE_ATTN:
        m = jnp.ones((q.shape[0], 1, q.shape[1], k.shape[1]), bool)
        if causal:
            m &= (q_pos[:, :, None] >= kv_pos[:, None, :])[:, None]
        if window is not None:
            m &= (q_pos[:, :, None] - window < kv_pos[:, None, :])[:, None]
        return _attend_dense(q, k, v, m, scale, softcap)
    return _flash_vjp(q, k, v, q_pos, kv_pos, causal, window, float(scale),
                      int(q_chunk), int(kv_chunk),
                      None if softcap is None else float(softcap))


def _flash_fwd_only(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                    scale, q_chunk=512, kv_chunk=1024, softcap=None):
    """Streaming-softmax attention via scan over kv chunks nested in a
    scan over q chunks. q (B,Sq,H,D); k/v (B,Skv,KV,D) with GQA.
    q_pos (B,Sq), kv_pos (B,Skv) absolute positions for masking."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    pad_q = (-Sq) % q_chunk
    pad_kv = (-Skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)),
                         constant_values=jnp.iinfo(jnp.int32).max)
    nq, nkv = q.shape[1] // q_chunk, k.shape[1] // kv_chunk
    qs = (q * scale).astype(jnp.float32).reshape(B, nq, q_chunk, KV, g, D)
    qs = jnp.moveaxis(qs, 1, 0)                      # (nq,B,qc,KV,g,D)
    qp = jnp.moveaxis(q_pos.reshape(B, nq, q_chunk), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nkv, kv_chunk, KV, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nkv, kv_chunk, KV, Dv), 1, 0)
    kp = jnp.moveaxis(kv_pos.reshape(B, nkv, kv_chunk), 1, 0)

    def q_body(_, q_blk):
        qi, qpi = q_blk

        def kv_body(carry, kv_blk):
            m_prev, l_prev, acc = carry
            kj, vj, kpj = kv_blk
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qi,
                                kj.astype(jnp.float32))
            if softcap:
                logits = jnp.tanh(logits / softcap) * softcap
            mask = jnp.ones((B, 1, 1, q_chunk, kv_chunk), bool)
            if causal:
                mask &= (kpj[:, None, None, None, :] <=
                         qpi[:, None, None, :, None])
            if window is not None:
                mask &= (kpj[:, None, None, None, :] >
                         qpi[:, None, None, :, None] - window)
            mask &= (kpj < jnp.iinfo(jnp.int32).max)[:, None, None, None, :]
            logits = jnp.where(mask, logits, -1e30)
            m_new = jnp.maximum(m_prev, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc), ()

        init = (jnp.full((B, KV, g, q_chunk), -jnp.inf),
                jnp.zeros((B, KV, g, q_chunk)),
                jnp.zeros((B, KV, g, q_chunk, Dv)))
        (m, l, acc), _ = lax.scan(kv_body, init, (ks, vs, kp))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, jnp.moveaxis(out, 3, 1)          # (B,qc,KV,g,D)

    _, outs = lax.scan(q_body, None, (qs, qp))        # (nq,B,qc,KV,g,Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq].astype(q.dtype) if pad_q else out.astype(q.dtype)


# --- flash attention with recomputing (flash) backward ----------------

def _flash_chunks(q, k, v, q_pos, kv_pos, q_chunk, kv_chunk):
    """Pad to chunk multiples and reorder into per-chunk stacks."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    pad_q = (-Sq) % q_chunk
    pad_kv = (-Skv) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)),
                         constant_values=jnp.iinfo(jnp.int32).max)
    nq, nkv = q.shape[1] // q_chunk, k.shape[1] // kv_chunk
    f32 = jnp.float32
    return {
        "qs": jnp.moveaxis(q.astype(f32).reshape(B, nq, q_chunk, KV, g, D),
                           1, 0),
        "qp": jnp.moveaxis(q_pos.reshape(B, nq, q_chunk), 1, 0),
        "ks": jnp.moveaxis(k.astype(f32).reshape(B, nkv, kv_chunk, KV, D),
                           1, 0),
        "vs": jnp.moveaxis(v.astype(f32).reshape(B, nkv, kv_chunk, KV, Dv),
                           1, 0),
        "kp": jnp.moveaxis(kv_pos.reshape(B, nkv, kv_chunk), 1, 0),
        "dims": (B, Sq, Skv, H, KV, g, D, Dv, nq, nkv, q_chunk, kv_chunk,
                 pad_q, pad_kv),
    }


def _chunk_mask(qpi, kpj, causal, window):
    """(B,1,1,qc,kvc) validity mask from absolute positions."""
    m = (kpj < jnp.iinfo(jnp.int32).max)[:, None, None, None, :]
    m = m & jnp.ones_like(qpi, bool)[:, None, None, :, None]
    if causal:
        m &= (kpj[:, None, None, None, :] <= qpi[:, None, None, :, None])
    if window is not None:
        m &= (kpj[:, None, None, None, :] >
              qpi[:, None, None, :, None] - window)
    return m


def _flash_fwd_core(c, causal, window, scale, softcap):
    """Returns outs (nq,B,KV,g,qc,Dv) f32 and lses (nq,B,KV,g,qc) f32."""
    B, Sq, Skv, H, KV, g, D, Dv, nq, nkv, qc, kvc, _, _ = c["dims"]

    def q_body(_, blk):
        qi, qpi = blk

        def kv_body(carry, kvb):
            m_prev, l_prev, acc = carry
            kj, vj, kpj = kvb
            z = scale * jnp.einsum("bqkgd,bskd->bkgqs", qi, kj)
            if softcap:
                z = jnp.tanh(z / softcap) * softcap
            z = jnp.where(_chunk_mask(qpi, kpj, causal, window), z, -1e30)
            m_new = jnp.maximum(m_prev, z.max(-1))
            p = jnp.exp(z - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd",
                                                     p, vj)
            return (m_new, l_new, acc), ()

        init = (jnp.full((B, KV, g, qc), -jnp.inf),
                jnp.zeros((B, KV, g, qc)),
                jnp.zeros((B, KV, g, qc, Dv)))
        (m, l, acc), _ = lax.scan(kv_body, init, (c["ks"], c["vs"], c["kp"]))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        return None, (out, lse)

    _, (outs, lses) = lax.scan(q_body, None, (c["qs"], c["qp"]))
    return outs, lses


def _flash_call(q, k, v, q_pos, kv_pos, causal, window, scale, q_chunk,
                kv_chunk, softcap):
    c = _flash_chunks(q, k, v, q_pos, kv_pos, q_chunk, kv_chunk)
    B, Sq, Skv, H, KV, g, D, Dv, nq, nkv, qc, kvc, pad_q, _ = c["dims"]
    outs, lses = _flash_fwd_core(c, causal, window, scale, softcap)
    out = jnp.moveaxis(outs, 4, 2)                    # (nq,B,qc,KV,g,Dv)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * qc, H, Dv)
    out = out[:, :Sq] if pad_q else out
    return out.astype(q.dtype), (outs, lses)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def _flash_vjp(q, k, v, q_pos, kv_pos, causal, window, scale, q_chunk,
               kv_chunk, softcap):
    return _flash_call(q, k, v, q_pos, kv_pos, causal, window, scale,
                       q_chunk, kv_chunk, softcap)[0]


def _flash_vjp_fwd(q, k, v, q_pos, kv_pos, causal, window, scale, q_chunk,
                   kv_chunk, softcap):
    out, (outs, lses) = _flash_call(q, k, v, q_pos, kv_pos, causal, window,
                                    scale, q_chunk, kv_chunk, softcap)
    return out, (q, k, v, q_pos, kv_pos, outs, lses)


def _flash_vjp_bwd(causal, window, scale, q_chunk, kv_chunk, softcap,
                   res, dout):
    import numpy as onp
    q, k, v, q_pos, kv_pos, outs, lses = res
    c = _flash_chunks(q, k, v, q_pos, kv_pos, q_chunk, kv_chunk)
    B, Sq, Skv, H, KV, g, D, Dv, nq, nkv, qc, kvc, pad_q, pad_kv = c["dims"]
    if pad_q:
        dout = jnp.pad(dout, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    douts = jnp.moveaxis(
        dout.astype(jnp.float32).reshape(B, nq, qc, KV, g, Dv), 1, 0)
    douts = jnp.moveaxis(douts, 2, 4)                 # (nq,B,KV,g,qc,Dv)
    Dres = jnp.sum(douts * outs, -1)                  # (nq,B,KV,g,qc)

    def q_body(carry, blk):
        dk_all, dv_all = carry
        qi, qpi, lse_i, dout_i, D_i = blk

        def kv_body(inner, kvb):
            dq_i, dk_all, dv_all = inner
            kj, vj, kpj, j = kvb
            z = scale * jnp.einsum("bqkgd,bskd->bkgqs", qi, kj)
            if softcap:
                t = jnp.tanh(z / softcap)
                zc = jnp.where(_chunk_mask(qpi, kpj, causal, window),
                               t * softcap, -1e30)
            else:
                zc = jnp.where(_chunk_mask(qpi, kpj, causal, window),
                               z, -1e30)
            p = jnp.exp(zc - lse_i[..., None])        # (B,KV,g,qc,kvc)
            dv_j = jnp.einsum("bkgqs,bkgqd->bskd", p, dout_i)
            dp = jnp.einsum("bkgqd,bskd->bkgqs", dout_i, vj)
            ds = p * (dp - D_i[..., None])
            if softcap:
                ds = ds * (1.0 - t * t)
            dq_i = dq_i + scale * jnp.einsum("bkgqs,bskd->bqkgd", ds, kj)
            dk_j = scale * jnp.einsum("bkgqs,bqkgd->bskd", ds, qi)
            return (dq_i, dk_all.at[j].add(dk_j),
                    dv_all.at[j].add(dv_j)), ()

        init_dq = jnp.zeros((B, qc, KV, g, D))
        (dq_i, dk_all, dv_all), _ = lax.scan(
            kv_body, (init_dq, dk_all, dv_all),
            (c["ks"], c["vs"], c["kp"], jnp.arange(nkv)))
        return (dk_all, dv_all), dq_i

    (dk_all, dv_all), dqs = lax.scan(
        q_body,
        (jnp.zeros((nkv, B, kvc, KV, D)), jnp.zeros((nkv, B, kvc, KV, Dv))),
        (c["qs"], c["qp"], lses, douts, Dres))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, nq * qc, H, D)[:, :Sq]
    dk = jnp.moveaxis(dk_all, 0, 1).reshape(B, nkv * kvc, KV, D)[:, :Skv]
    dv = jnp.moveaxis(dv_all, 0, 1).reshape(B, nkv * kvc, KV, Dv)[:, :Skv]
    zq = onp.zeros(q_pos.shape, dtype=jax.dtypes.float0)
    zk = onp.zeros(kv_pos.shape, dtype=jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zq, zk)


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)

# ----------------------------------------------------------------------
# GQA attention block (full & SWA), with decode caches
# ----------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = _split(key, 4)
    return {
        "wq": dense_init(ks[0], d, H * Dh, bias=cfg.attn_bias, dtype=dtype),
        "wk": dense_init(ks[1], d, KV * Dh, bias=cfg.attn_bias, dtype=dtype),
        "wv": dense_init(ks[2], d, KV * Dh, bias=cfg.attn_bias, dtype=dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype=dtype),
    }


def attention_apply(p, cfg: ModelConfig, x, positions, *, mode, state,
                    local: bool = False, cross_kv=None):
    """GQA attention. local=True uses cfg.rglru.local_window (hybrid) or
    cfg.sliding_window. cross_kv: (k, v, kv_pos) for cross-attention
    (whisper decoder) — no cache mutation, no rope on kv."""
    B, S, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    window = None
    if local:
        window = (cfg.rglru.local_window if cfg.rglru else cfg.sliding_window)
    scale = Dh ** -0.5
    q = constrain(dense(p["wq"], x).reshape(B, S, H, Dh),
                  "dp", None, "tp", None)
    if cross_kv is None:
        # GQA with few kv heads: kv is replicated over tp (Megatron GQA)
        k = constrain(dense(p["wk"], x).reshape(B, S, KV, Dh),
                      "dp", None, None, None)
        v = constrain(dense(p["wv"], x).reshape(B, S, KV, Dh),
                      "dp", None, None, None)
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
    else:
        k, v, kv_pos = cross_kv

    pos1d = positions[..., 0] if positions.ndim == 3 else positions
    if mode == "full":
        if cross_kv is not None:
            out = _attend_dense(q, k, v, None, scale, cfg.logit_softcap)
        else:
            out = flash_attention(q, k, v, q_pos=pos1d, kv_pos=pos1d,
                                  causal=True, window=window, scale=scale,
                                  softcap=cfg.logit_softcap)
        new_state = state
        if state is not None and cross_kv is None:   # prefill fills cache
            new_state = _cache_fill(state, k, v, pos1d, window)
    else:  # step: S == 1
        if cross_kv is not None:
            mask = None
            out = _attend_dense(q, k, v, mask, scale, cfg.logit_softcap)
            new_state = state
        else:
            state = _cache_append(state, k, v, pos1d, window)
            ck, cv, cpos = state["k"], state["v"], state["pos_abs"]
            mask = ((cpos <= pos1d) & (cpos >= 0))
            if window is not None:
                mask &= cpos > pos1d - window
            mask = mask[:, None, None, :]            # (B,1,1,T)
            out = _attend_dense(q, ck, cv, mask, scale, cfg.logit_softcap)
            new_state = state
    y = dense(p["wo"], constrain(out.reshape(B, S, H * Dh),
                                 "dp", None, "tp"))
    return y, new_state


def constrain_cache(state: dict) -> dict:
    """Shard decode caches: batch over dp and cache-sequence over tp
    (context parallelism); for B=1 long-context decode the sequence dim
    takes both axes."""
    out = {}
    for name, c in state.items():
        if c.ndim >= 2 and c.shape[0] == 1:
            out[name] = constrain(c, None, "dpt", *([None] * (c.ndim - 2)))
        elif c.ndim >= 2:
            out[name] = constrain(c, "dp", "tp", *([None] * (c.ndim - 2)))
        else:
            out[name] = constrain(c, "dp")
    return out


def init_attn_cache(cfg: ModelConfig, B, max_len, *, window=None,
                    dtype=jnp.bfloat16):
    T = min(window, max_len) if window else max_len
    KV, Dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((B, T, KV, Dh), dtype),
        "v": jnp.zeros((B, T, KV, Dh), dtype),
        "pos_abs": jnp.full((B, T), -1, jnp.int32),  # -1 = empty slot
    }


def _cache_append(state, k, v, pos, window):
    """Write one token (S==1) at pos (B,1). Ring buffer when windowed."""
    T = state["k"].shape[1]
    slot = (pos[:, 0] % T).astype(jnp.int32)         # (B,)
    bidx = jnp.arange(k.shape[0])
    return constrain_cache({
        "k": state["k"].at[bidx, slot].set(k[:, 0]),
        "v": state["v"].at[bidx, slot].set(v[:, 0]),
        "pos_abs": state["pos_abs"].at[bidx, slot].set(pos[:, 0]),
    })


def _cache_fill(state, k, v, pos, window):
    """Bulk prefill: write the last T positions into the cache."""
    T = state["k"].shape[1]
    S = k.shape[1]
    if S >= T:
        ks, vs, ps = k[:, -T:], v[:, -T:], pos[:, -T:]
        slot = ps % T
        bidx = jnp.arange(k.shape[0])[:, None]
        return constrain_cache({
            "k": state["k"].at[bidx, slot].set(ks),
            "v": state["v"].at[bidx, slot].set(vs),
            "pos_abs": state["pos_abs"].at[bidx, slot].set(ps),
        })
    slot = pos % T
    bidx = jnp.arange(k.shape[0])[:, None]
    return constrain_cache({
        "k": state["k"].at[bidx, slot].set(k),
        "v": state["v"].at[bidx, slot].set(v),
        "pos_abs": state["pos_abs"].at[bidx, slot].set(pos),
    })

# ----------------------------------------------------------------------
# MLA (deepseek-v3) — latent-compressed KV
# ----------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = _split(key, 7)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype=dtype),
        "q_norm": norm_init(m.q_lora_rank),
        "w_uq": dense_init(ks[1], m.q_lora_rank, H * qk_dim, dtype=dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype=dtype),
        "kv_norm": norm_init(m.kv_lora_rank),
        "w_kr": dense_init(ks[3], d, m.qk_rope_dim, dtype=dtype),
        "w_uk": dense_init(ks[4], m.kv_lora_rank, H * m.qk_nope_dim, dtype=dtype),
        "w_uv": dense_init(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype=dtype),
        "wo": dense_init(ks[6], H * m.v_head_dim, d, dtype=dtype),
    }


def _mla_rope(x, positions, cfg):
    sub = dataclasses.replace(cfg, rope_style="full", rope_frac=1.0)
    return apply_rope(x, positions, sub)


def mla_apply(p, cfg: ModelConfig, x, positions, *, mode, state):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    pos1d = positions[..., 0] if positions.ndim == 3 else positions
    q = dense(p["w_uq"], apply_norm(p["q_norm"], dense(p["w_dq"], x)))
    q = q.reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = _mla_rope(q_rope, pos1d, cfg)
    c = apply_norm(p["kv_norm"], dense(p["w_dkv"], x))        # (B,S,r)
    k_rope = _mla_rope(dense(p["w_kr"], x)[:, :, None, :], pos1d, cfg)

    if mode == "step" and state is not None:
        T = state["c"].shape[1]
        slot = pos1d[:, 0] % T
        bidx = jnp.arange(B)
        state = constrain_cache({
            "c": state["c"].at[bidx, slot].set(c[:, 0]),
            "kr": state["kr"].at[bidx, slot].set(k_rope[:, 0, 0]),
            "pos_abs": state["pos_abs"].at[bidx, slot].set(pos1d[:, 0]),
        })
        c_all, kr_all, kv_pos = state["c"], state["kr"], state["pos_abs"]
    else:
        c_all, kr_all, kv_pos = c, k_rope[:, :, 0, :], pos1d
        if state is not None:   # prefill fills latent cache
            T = state["c"].shape[1]
            slot = pos1d % T
            bidx = jnp.arange(B)[:, None]
            state = constrain_cache({
                "c": state["c"].at[bidx, slot].set(c),
                "kr": state["kr"].at[bidx, slot].set(k_rope[:, :, 0, :]),
                "pos_abs": state["pos_abs"].at[bidx, slot].set(pos1d),
            })
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    if mode == "full":
        # Prefill/train: reconstruct per-head k/v once for the whole
        # sequence (standard MLA prefill).
        T = c_all.shape[1]
        k_nope = dense(p["w_uk"], c_all).reshape(B, T, H, m.qk_nope_dim)
        val = dense(p["w_uv"], c_all).reshape(B, T, H, m.v_head_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                      (B, T, H, m.qk_rope_dim))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = flash_attention(q_full, k, val, q_pos=pos1d, kv_pos=kv_pos,
                              causal=True, window=None, scale=scale)
    else:
        # Decode: ABSORBED form — attention runs in the latent space, so
        # per-token cost is O(T*r), never materializing per-head k/v.
        r = m.kv_lora_rank
        w_uk = p["w_uk"]["w"].reshape(r, H, m.qk_nope_dim)
        w_uv = p["w_uv"]["w"].reshape(r, H, m.v_head_dim)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))          # (B,1,H,r)
        cf = c_all.astype(jnp.float32)
        logits = (jnp.einsum("bshr,btr->bhst", q_lat, cf)
                  + jnp.einsum("bshe,bte->bhst",
                               q_rope.astype(jnp.float32),
                               kr_all.astype(jnp.float32))) * scale
        mask = ((kv_pos <= pos1d) & (kv_pos >= 0))[:, None, None, :]
        logits = jnp.where(mask, logits, -1e30)
        attn = jax.nn.softmax(logits, axis=-1)                # (B,H,1,T)
        out_lat = jnp.einsum("bhst,btr->bshr", attn, cf)      # (B,1,H,r)
        out = jnp.einsum("bshr,rhv->bshv", out_lat,
                         w_uv.astype(jnp.float32)).astype(x.dtype)
    y = dense(p["wo"], out.reshape(B, S, H * m.v_head_dim))
    return y, state


def init_mla_cache(cfg: ModelConfig, B, max_len, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c": jnp.zeros((B, max_len, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((B, max_len, m.qk_rope_dim), dtype),
        "pos_abs": jnp.full((B, max_len), -1, jnp.int32),
    }

# ----------------------------------------------------------------------
# MLP + MoE
# ----------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff=None, dtype=jnp.bfloat16):
    d_ff = d_ff or cfg.d_ff
    ks = _split(key, 3)
    p = {"w_up": dense_init(ks[0], cfg.d_model, d_ff, bias=cfg.mlp_bias,
                            dtype=dtype),
         "w_down": dense_init(ks[1], d_ff, cfg.d_model, bias=cfg.mlp_bias,
                              dtype=dtype)}
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[2], cfg.d_model, d_ff, bias=cfg.mlp_bias,
                                 dtype=dtype)
    return p


def mlp_apply(p, cfg: ModelConfig, x):
    act = _ACTS[cfg.mlp_act]
    h = act(dense(p["w_up"], x)) if "w_gate" not in p else (
        act(dense(p["w_gate"], x)) * dense(p["w_up"], x))
    if h.ndim == 3:
        h = constrain(h, "dp", None, "tp")
    return dense(p["w_down"], h)


def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    mo = cfg.moe
    d, dff = cfg.d_model, mo.d_expert
    ks = _split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, mo.num_experts, dtype=jnp.float32),
        "w_up": (jax.random.normal(ks[1], (mo.num_experts, d, dff),
                                   jnp.float32) * scale).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (mo.num_experts, d, dff),
                                     jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (mo.num_experts, dff, d),
                                     jnp.float32) / math.sqrt(dff)).astype(dtype),
    }
    if mo.num_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=mo.num_shared * dff,
                               dtype=dtype)
    return p


def _route(router_p, mo, xt):
    """Router: (gates, experts, aux_loss) for tokens xt (T, d)."""
    logits = dense(router_p, xt.astype(jnp.float32))          # (T,E)
    probs = jax.nn.softmax(logits, -1)
    gates, experts = lax.top_k(probs, mo.top_k)               # (T,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style, over all top-k assignments)
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(experts, mo.num_experts), axis=1), 0) / mo.top_k
    mean_prob = jnp.mean(probs, 0)
    aux = mo.num_experts * jnp.sum(density * mean_prob) * mo.router_aux_coef
    return gates, experts, aux


def _dispatch_tables(experts, gates, T, mo, C):
    """Sort-based capacity dispatch tables: tok_idx (E,C), gate_val (E,C)."""
    flat_e = experts.reshape(-1)                              # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), mo.top_k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    seg_counts = jnp.bincount(se, length=mo.num_experts)
    seg_start = jnp.cumsum(seg_counts) - seg_counts
    pos_in_seg = jnp.arange(T * mo.top_k) - seg_start[se]
    keep = pos_in_seg < C
    slot_e = jnp.where(keep, se, mo.num_experts)              # overflow bin
    slot_c = jnp.where(keep, pos_in_seg, 0)
    tok_idx = jnp.zeros((mo.num_experts + 1, C), jnp.int32).at[
        slot_e, slot_c].set(st.astype(jnp.int32))[: mo.num_experts]
    gate_val = jnp.zeros((mo.num_experts + 1, C), flat_g.dtype).at[
        slot_e, slot_c].set(jnp.where(keep, sg, 0.0))[: mo.num_experts]
    return tok_idx, gate_val


def _expert_ffn(cfg, xe, wg, wu, wd):
    """Batched expert matmuls. xe (E, C, d) with E local/sharded."""
    act = _ACTS[cfg.mlp_act]
    h = act(jnp.einsum("ecd,edf->ecf", xe, wg)) \
        * jnp.einsum("ecd,edf->ecf", xe, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)                  # (E,C,d)


def _capacity(mo, T, no_drop):
    if no_drop:
        return T * mo.top_k
    return max(1, int(mo.capacity_factor * mo.top_k * T / mo.num_experts))


def moe_apply(p, cfg: ModelConfig, x, *, no_drop: bool = False):
    """Mixture-of-experts channel block. Returns (y, aux_loss).

    Two execution paths:
      * distributed (launcher bound a mesh): shard_map expert parallelism
        — tokens stay on their data shard, routing is local, and expert
        slabs move via all-to-all over the model axis (the canonical EP
        communication pattern). Tokens over local capacity are dropped.
      * single-host / CPU tests: global sort-based capacity dispatch.
    ``no_drop=True`` (decode, tiny T) sizes capacity so routing is exact.
    """
    mesh = _AXES.get("mesh")
    if mesh is not None and _AXES["dp"] is not None:
        B = x.shape[0]
        import numpy as _np
        dp = _AXES["dp"] if isinstance(_AXES["dp"], tuple) else (_AXES["dp"],)
        dp_size = int(_np.prod([mesh.shape[a] for a in dp]))
        if B % dp_size == 0 and cfg.moe.num_experts % mesh.shape[_AXES["tp"]] == 0:
            return _moe_sharded(p, cfg, x, no_drop=no_drop)
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    gates, experts, aux = _route(p["router"], mo, xt)
    C = _capacity(mo, T, no_drop)
    tok_idx, gate_val = _dispatch_tables(experts, gates, T, mo, C)
    xe = constrain(xt[tok_idx], "tp", None, None)             # (E,C,d)
    ye = _expert_ffn(cfg, xe, p["w_gate"], p["w_up"], p["w_down"])
    ye = ye * gate_val[..., None].astype(ye.dtype)
    y = jnp.zeros((T, d), ye.dtype).at[tok_idx.reshape(-1)].add(
        ye.reshape(-1, d))
    if "shared" in p:
        y = y + mlp_apply(p["shared"], cfg, xt)
    return y.reshape(B, S, d), aux


def _moe_sharded(p, cfg: ModelConfig, x, *, no_drop: bool):
    """shard_map expert parallelism: local routing per data shard, expert
    slabs exchanged via all-to-all over the model (expert) axis."""
    from jax.sharding import PartitionSpec as P

    mo = cfg.moe
    mesh = _AXES["mesh"]
    tp = _AXES["tp"]
    dp = _AXES["dp"] if isinstance(_AXES["dp"], tuple) else (_AXES["dp"],)
    B, S, d = x.shape
    import numpy as _np
    dp_size = int(_np.prod([mesh.shape[a] for a in dp]))
    tp_size = int(mesh.shape[tp])
    E = mo.num_experts
    assert E % tp_size == 0, (E, tp_size)
    T_loc = (B // dp_size) * S
    C_loc = _capacity(mo, T_loc, no_drop)

    def local_fn(xb, router_w, wg, wu, wd, shared):
        Bl, Sl, _ = xb.shape
        xt = xb.reshape(Bl * Sl, d)
        gates, experts, aux = _route({"w": router_w}, mo, xt)
        tok_idx, gate_val = _dispatch_tables(experts, gates, Bl * Sl, mo,
                                             C_loc)
        xe = xt[tok_idx]                                      # (E, C_loc, d)
        # expert slabs to their owners: (E, C, d) -> (E/tp, tp*C, d)
        xe = lax.all_to_all(xe, tp, split_axis=0, concat_axis=1, tiled=True)
        ye = _expert_ffn(cfg, xe, wg, wu, wd)
        ye = lax.all_to_all(ye, tp, split_axis=1, concat_axis=0, tiled=True)
        ye = ye * gate_val[..., None].astype(ye.dtype)
        y = jnp.zeros((Bl * Sl, d), ye.dtype).at[
            tok_idx.reshape(-1)].add(ye.reshape(-1, d))
        if shared is not None:
            # shared expert: Megatron col/row split over tp + psum
            act = _ACTS[cfg.mlp_act]
            h = act(xt @ shared["w_gate"]["w"]) * (xt @ shared["w_up"]["w"])
            y = y + lax.psum(h @ shared["w_down"]["w"], tp)
        aux = lax.pmean(aux, dp)
        return y.reshape(Bl, Sl, d), aux[None]

    shared_p = p.get("shared") or {}
    shared_specs = {}
    if shared_p:
        shared_specs = {"w_gate": {"w": P(None, tp)},
                        "w_up": {"w": P(None, tp)},
                        "w_down": {"w": P(tp, None)}}
    from repro.utils import compat
    y, aux = compat.shard_map(
        local_fn, mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(tp, None, None), P(tp, None, None), P(tp, None, None),
                  shared_specs),
        out_specs=(P(dp, None, None), P(None)),
    )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"], shared_p)
    return y, aux[0]

# ----------------------------------------------------------------------
# causal depthwise conv1d (griffin / mamba2 frontends)
# ----------------------------------------------------------------------

def init_conv1d(key, width, d, dtype=jnp.bfloat16):
    return {"w": (jax.random.normal(key, (width, d), jnp.float32)
                  / math.sqrt(width)).astype(dtype),
            "b": jnp.zeros((d,), dtype)}


def conv1d_apply(p, x, *, mode, state):
    """x (B,S,d). state (B,width-1,d) holds the trailing context."""
    width = p["w"].shape[0]
    if mode == "full":
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
        y = sum(xp[:, i: i + x.shape[1]] * p["w"][i] for i in range(width))
        new_state = None if state is None else xp[:, -(width - 1):]
        return y + p["b"], new_state
    # step: S == 1
    ctx = jnp.concatenate([state, x], 1)                      # (B,width,d)
    y = jnp.einsum("bwd,wd->bd", ctx, p["w"])[:, None] + p["b"]
    return y, ctx[:, 1:]

# ----------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma / griffin)
# ----------------------------------------------------------------------

def init_rglru(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    width = cfg.rglru.lru_width or cfg.d_model
    d = cfg.d_model
    ks = _split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, width, dtype=dtype),
        "w_gate_branch": dense_init(ks[1], d, width, dtype=dtype),
        "conv": init_conv1d(ks[2], cfg.rglru.d_conv, width, dtype=dtype),
        "w_rec_gate": dense_init(ks[3], width, width, dtype=dtype),
        "w_in_gate": dense_init(ks[4], width, width, dtype=dtype),
        # lam s.t. a = exp(-c*softplus(lam)) lands in ~(0.9, 0.999) at r=1
        "lam": jnp.log(jnp.expm1(
            jax.random.uniform(ks[5], (width,), jnp.float32,
                               0.0001, 0.013))),
        "w_out": dense_init(_split(ks[5], 2)[1], width, d, dtype=dtype),
    }


_RGLRU_C = 8.0


def _rglru_scan(x, r, i, lam):
    """x,r,i (B,S,W) f32. Associative scan over time of
    h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t), a_t = a^(c r_t)."""
    log_a = -_RGLRU_C * r * jax.nn.softplus(lam)              # log a_t
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * x)

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 * a2, a2 * h1 + h2

    a_s, h = lax.associative_scan(combine, (a, gated), axis=1)
    return h


def rglru_apply(p, cfg: ModelConfig, x, positions, *, mode, state):
    """Griffin recurrent block: gate branch (gelu) * recurrent branch
    (conv1d -> RG-LRU), then out-projection."""
    gate = constrain(jax.nn.gelu(dense(p["w_gate_branch"], x)),
                     "dp", None, "tp")
    u = constrain(dense(p["w_x"], x), "dp", None, "tp")
    conv_state = None if state is None else state["conv"]
    u, new_conv = conv1d_apply(p["conv"], u, mode=mode, state=conv_state)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(p["w_rec_gate"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_in_gate"], u).astype(jnp.float32))
    lam = p["lam"]
    if mode == "full":
        h = _rglru_scan(uf, r, i, lam)
        new_state = state
        if state is not None:
            new_state = {"h": h[:, -1].astype(jnp.float32), "conv": new_conv}
    else:
        log_a = -_RGLRU_C * r[:, 0] * jax.nn.softplus(lam)
        a = jnp.exp(log_a)
        h_prev = state["h"]
        h1 = a * h_prev + jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-6)) \
            * (i[:, 0] * uf[:, 0])
        h = h1[:, None]
        new_state = {"h": h1, "conv": new_conv}
    y = dense(p["w_out"], (h.astype(x.dtype) * gate))
    return y, new_state


def init_rglru_state(cfg: ModelConfig, B, dtype=jnp.bfloat16):
    width = cfg.rglru.lru_width or cfg.d_model
    return {"h": jnp.zeros((B, width), jnp.float32),
            "conv": jnp.zeros((B, cfg.rglru.d_conv - 1, width), dtype)}

# ----------------------------------------------------------------------
# Mamba-2 SSD block (state-space duality, chunked)
# ----------------------------------------------------------------------

def init_ssd(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.n_heads(d)
    ks = _split(key, 4)
    conv_dim = din + 2 * s.n_groups * s.d_state
    return {
        # in_proj -> [z (din), x (din), B (G*N), C (G*N), dt (nh)]
        "w_in": dense_init(ks[0], d, 2 * din + 2 * s.n_groups * s.d_state + nh,
                           dtype=dtype),
        "conv": init_conv1d(ks[1], s.d_conv, conv_dim, dtype=dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (nh,), jnp.float32, 1., 16.)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jax.random.uniform(
            ks[2], (nh,), jnp.float32, 1e-3, 1e-1))),
        "out_norm": norm_init(din),
        "w_out": dense_init(ks[3], din, d, dtype=dtype),
    }


def _ssd_chunked(x, dt, A, Bm, Cm, chunk):
    """Minimal SSD (mamba2 §6): x (B,S,H,P); dt (B,S,H); A (H,);
    Bm/Cm (B,S,G,N). Returns y (B,S,H,P), final_state (B,H,P,N)."""
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0
    nc = S // chunk
    rep = H // G
    x_ = x.reshape(b, nc, chunk, H, P)
    dt_ = dt.reshape(b, nc, chunk, H)
    B_ = jnp.repeat(Bm.reshape(b, nc, chunk, G, N), rep, axis=3)
    C_ = jnp.repeat(Cm.reshape(b, nc, chunk, G, N), rep, axis=3)
    dA = dt_ * (-jnp.exp(A))[None, None, None, :]             # (b,nc,c,H) <=0
    dA_cum = jnp.cumsum(dA, axis=2)
    # intra-chunk (quadratic within chunk). Mask BEFORE exp: the
    # upper-triangle segments are positive and exp() of them overflows,
    # which poisons gradients (inf * 0 = NaN in the backward pass).
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]  # (b,nc,c,c,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    scores = jnp.einsum("bzchn,bzshn->bzcsh", C_, B_)          # (b,nc,c,c,H)
    y_diag = jnp.einsum("bzcsh,bzcsh,bzsh,bzshp->bzchp",
                        scores, L, dt_, x_)
    # chunk end-states
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)      # (b,nc,c,H)
    states = jnp.einsum("bzch,bzch,bzchn,bzchp->bzhpn",
                        decay_to_end, dt_, B_, x_)             # per-chunk
    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                 # (b,nc,H)

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h

    h0 = jnp.zeros((b, H, P, N), x.dtype)
    hT, h_prev = lax.scan(scan_fn, h0,
                          (jnp.moveaxis(states, 1, 0),
                           jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                        # (b,nc,H,P,N)
    decay_in = jnp.exp(dA_cum)                                 # (b,nc,c,H)
    y_off = jnp.einsum("bzchn,bzhpn,bzch->bzchp", C_, h_prev, decay_in)
    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, hT


def ssd_apply(p, cfg: ModelConfig, x, positions, *, mode, state):
    s = cfg.ssm
    B, S, d = x.shape
    din = s.d_inner(d)
    nh = s.n_heads(d)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    zxbcdt = dense(p["w_in"], x)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * G * N], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = conv1d_apply(p["conv"], xbc, mode=mode, state=conv_state)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [din, din + G * N], axis=-1)
    xs = constrain(xs.reshape(B, S, nh, P), "dp", None, "tp", None)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = p["A_log"]
    if mode == "full":
        pad = (-S) % s.chunk
        if pad:
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            xs_p, dt_p, Bm_p, Cm_p = xs, dt, Bm, Cm
        y, hT = _ssd_chunked(xs_p.astype(jnp.float32), dt_p,
                             A, Bm_p.astype(jnp.float32),
                             Cm_p.astype(jnp.float32), s.chunk)
        y = y[:, :S]
        new_state = state
        if state is not None:
            new_state = {"h": hT, "conv": new_conv}
    else:
        # recurrent step: h = exp(dt A) h + dt B x ; y = C h
        dA = jnp.exp(dt[:, 0] * (-jnp.exp(A)))                 # (B,nh)
        B_rep = jnp.repeat(Bm[:, 0], nh // G, axis=1)          # (B,nh,N)
        C_rep = jnp.repeat(Cm[:, 0], nh // G, axis=1)
        Bx = jnp.einsum("bhn,bhp,bh->bhpn", B_rep.astype(jnp.float32),
                        xs[:, 0].astype(jnp.float32), dt[:, 0])
        h = state["h"] * dA[..., None, None] + Bx
        y = jnp.einsum("bhpn,bhn->bhp", h, C_rep.astype(jnp.float32))
        y = y[:, None]                                         # (B,1,nh,P)
        new_state = {"h": h, "conv": new_conv}
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, din).astype(x.dtype)
    y = apply_norm(p["out_norm"], y * jax.nn.silu(z))
    return dense(p["w_out"], y), new_state


def init_ssd_state(cfg: ModelConfig, B, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    nh = s.n_heads(d)
    conv_dim = s.d_inner(d) + 2 * s.n_groups * s.d_state
    return {"h": jnp.zeros((B, nh, s.head_dim, s.d_state), jnp.float32),
            "conv": jnp.zeros((B, s.d_conv - 1, conv_dim), dtype)}
