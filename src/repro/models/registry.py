"""Uniform model interface over the decoder-LM and enc-dec families.

``build_model(cfg)`` returns a ``Model`` with:
  init(key)                          -> params
  forward_train(params, batch)       -> (logits, aux_loss)   [full seq]
  prefill(params, batch, states)     -> (logits, states)
  decode_step(params, batch, states) -> (logits, states)     [S == 1]
  init_states(params, B, max_len[, batch]) -> per-layer decode state
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models import whisper as W


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    def init(self, key, dtype=jnp.bfloat16):
        if self.cfg.family == "audio":
            return W.init_whisper(key, self.cfg, dtype)
        return T.init_lm(key, self.cfg, dtype)

    # ------------------------------------------------------------------
    def forward_train(self, params, batch, *, unroll: bool = False,
                      remat: bool = False):
        cfg = self.cfg
        if cfg.family == "audio":
            enc_out = W.encode(params, cfg, batch["frame_embeds"])
            logits, _ = W.decode(params, cfg, batch["tokens"], enc_out)
            return logits, jnp.zeros((), jnp.float32)
        if cfg.mtp_depth > 0 and not unroll:
            logits, hidden, aux = T.forward_hidden(params, cfg, batch,
                                                   remat=remat)
            # MTP logits are consumed by the loss; return both via aux dict
            return logits, aux
        logits, _, aux = T.forward(params, cfg, batch, mode="full",
                                   states=None, unroll=unroll, remat=remat)
        return logits, aux

    def forward_train_mtp(self, params, batch, *, unroll: bool = False,
                          remat: bool = False):
        """Train forward returning MTP head logits too (deepseek-v3)."""
        cfg = self.cfg
        logits, hidden, aux = T.forward_hidden(params, cfg, batch,
                                               remat=remat, unroll=unroll)
        mtp = T.mtp_logits(params, cfg, hidden, batch)
        return logits, mtp, aux

    # ------------------------------------------------------------------
    def prefill(self, params, batch, states, *, last_logits_only=False,
                unroll=False):
        cfg = self.cfg
        if cfg.family == "audio":
            enc_out = W.encode(params, cfg, batch["frame_embeds"])
            states = W.init_whisper_states(
                params, cfg, batch["tokens"].shape[0],
                states_max_len(states), enc_out)
            logits, states = W.decode(params, cfg, batch["tokens"], enc_out,
                                      mode="full", states=states)
            if last_logits_only:
                logits = logits[:, -1:]
            return logits, states
        logits, states, _ = T.forward(params, cfg, batch, mode="full",
                                      states=states, unroll=unroll,
                                      last_logits_only=last_logits_only)
        return logits, states

    # ------------------------------------------------------------------
    def decode_step(self, params, batch, states):
        cfg = self.cfg
        if cfg.family == "audio":
            logits, states = W.decode(params, cfg, batch["tokens"], None,
                                      mode="step", states=states,
                                      positions=batch["positions"])
            return logits, states
        logits, states, _ = T.forward(params, cfg, batch, mode="step",
                                      states=states)
        return logits, states

    # ------------------------------------------------------------------
    def init_states(self, params, B: int, max_len: int, batch=None,
                    dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family == "audio":
            assert batch is not None and "frame_embeds" in batch
            enc_out = W.encode(params, cfg, batch["frame_embeds"])
            return W.init_whisper_states(params, cfg, B, max_len, enc_out,
                                         dtype)
        return T.init_states(cfg, B, max_len, dtype)


def states_max_len(states) -> int:
    for st in states:
        if isinstance(st, dict) and "self" in st:
            return st["self"]["k"].shape[1]
        if isinstance(st, dict) and "k" in st:
            return st["k"].shape[1]
    return 0


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
