"""Decoder-only LM assembling the block zoo into the assigned archs.

A *layer* = (mixer, channel) pair with pre-norm residuals:
  mixer   : attn | attn_local | mla | rglru | ssd
  channel : mlp | moe | none

``cfg.block_pattern`` lists the mixer kinds cycled over layers; the
channel kind is derived per-arch (MoE archs route all-but-first_k_dense
layers through MoE; mamba2 has no separate channel block).

Layers are stored STACKED per pattern-slot so the forward pass can scan
over layer periods (compile time independent of depth for the 48-80
layer production configs). ``unroll=True`` switches to a python loop
over static slices of the same stacked params — used by the roofline
pass, where scan bodies would be cost-counted only once.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig, padded_vocab
from repro.models import layers as L


# ----------------------------------------------------------------------
# layer templates
# ----------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> list[tuple[str, str]]:
    """[(mixer, channel)] for every layer."""
    plan = []
    pat = cfg.block_pattern
    for i in range(cfg.num_layers):
        mixer = pat[i % len(pat)]
        if mixer == "ssd":
            channel = "none"
        elif cfg.moe is not None and i >= cfg.moe.first_k_dense:
            channel = "moe"
        else:
            channel = "mlp"
        if cfg.mla is not None and mixer == "attn":
            mixer = "mla"
        plan.append((mixer, channel))
    return plan


def _period(cfg: ModelConfig) -> int:
    """Smallest cycle after which the (mixer, channel) plan repeats."""
    plan = layer_plan(cfg)
    base = len(cfg.block_pattern)
    k = cfg.moe.first_k_dense if cfg.moe else 0
    # prologue layers (first_k_dense) are kept out of the scanned stack
    body = plan[k:]
    p = base
    while any(body[i] != body[i % p] for i in range(len(body))):
        p += base
    return p


def _init_mixer(key, cfg, kind, dtype):
    if kind in ("attn", "attn_local"):
        return L.init_attention(key, cfg, dtype)
    if kind == "mla":
        return L.init_mla(key, cfg, dtype)
    if kind == "rglru":
        return L.init_rglru(key, cfg, dtype)
    if kind == "ssd":
        return L.init_ssd(key, cfg, dtype)
    raise ValueError(kind)


def _apply_mixer(p, cfg, kind, x, positions, mode, state):
    if kind == "attn":
        return L.attention_apply(p, cfg, x, positions, mode=mode, state=state,
                                 local=cfg.sliding_window is not None)
    if kind == "attn_local":
        return L.attention_apply(p, cfg, x, positions, mode=mode, state=state,
                                 local=True)
    if kind == "mla":
        return L.mla_apply(p, cfg, x, positions, mode=mode, state=state)
    if kind == "rglru":
        return L.rglru_apply(p, cfg, x, positions, mode=mode, state=state)
    if kind == "ssd":
        return L.ssd_apply(p, cfg, x, positions, mode=mode, state=state)
    raise ValueError(kind)


def _init_layer(key, cfg, mixer, channel, dtype):
    k1, k2 = jax.random.split(key)
    p = {"mixer_norm": L.norm_init(cfg.d_model, cfg.norm),
         "mixer": _init_mixer(k1, cfg, mixer, dtype)}
    if channel == "mlp":
        p["channel"] = L.init_mlp(k2, cfg, dtype=dtype)
        p["channel_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    elif channel == "moe":
        p["channel"] = L.init_moe(k2, cfg, dtype=dtype)
        p["channel_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    return p


def _apply_layer(p, cfg, mixer, channel, x, positions, mode, state):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    # Megatron-style sequence parallelism: the residual stream (and thus
    # the remat-saved per-layer activation stack) is sharded over the
    # model axis on the sequence dim; GSPMD turns the TP all-reduces
    # into reduce-scatter + all-gather pairs around the matmuls.
    x = L.constrain(x, "dp", "tp", None)
    h_in = L.apply_norm(p["mixer_norm"], x, cfg.norm)
    h, new_state = _apply_mixer(p["mixer"], cfg, mixer, h_in, positions,
                                mode, state)
    # block outputs are row-parallel partial sums; constraining them
    # sequence-sharded turns the TP all-reduce into a reduce-scatter
    # (half the bytes), the Megatron-SP schedule.
    if mode == "full":
        h = L.constrain(h, "dp", "tp", None)
    if cfg.parallel_block and channel != "none":
        # command-r style: attn and mlp read the same normed input
        c = L.mlp_apply(p["channel"], cfg, h_in)
        if mode == "full":
            c = L.constrain(c, "dp", "tp", None)
        x = x + h + c
        return x, new_state, aux
    x = x + h
    if channel == "mlp":
        y = L.mlp_apply(p["channel"],
                        cfg, L.apply_norm(p["channel_norm"], x, cfg.norm))
        x = x + (L.constrain(y, "dp", "tp", None) if mode == "full" else y)
    elif channel == "moe":
        y, aux = L.moe_apply(p["channel"], cfg,
                             L.apply_norm(p["channel_norm"], x, cfg.norm),
                             no_drop=(mode == "step"))
        x = x + (L.constrain(y, "dp", "tp", None) if mode == "full" else y)
    return x, new_state, aux


# ----------------------------------------------------------------------
# whole-model init
# ----------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    v = padded_vocab(cfg)
    plan = layer_plan(cfg)
    k_dense = cfg.moe.first_k_dense if cfg.moe else 0
    period = _period(cfg)
    body = plan[k_dense:]
    assert len(body) % period == 0, (cfg.name, len(body), period)
    n_cycles = len(body) // period

    keys = jax.random.split(key, cfg.num_layers + 4)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[-1], (v, cfg.d_model), jnp.float32)
                  * 0.02).astype(dtype),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(
            keys[-2], (cfg.d_model, v), jnp.float32)
            / np.sqrt(cfg.d_model)).astype(dtype)
    params["prologue"] = [
        _init_layer(keys[i], cfg, *plan[i], dtype) for i in range(k_dense)]
    stacks = []
    for s in range(period):
        per_cycle = [
            _init_layer(keys[k_dense + c * period + s], cfg,
                        *body[c * period + s], dtype)
            for c in range(n_cycles)]
        stacks.append(_stack(per_cycle))
    params["stack"] = stacks
    if cfg.mtp_depth > 0:
        km = jax.random.split(keys[-3], 3)
        params["mtp"] = {
            "proj": L.dense_init(km[0], 2 * cfg.d_model, cfg.d_model,
                                 dtype=dtype),
            "norm": L.norm_init(cfg.d_model, cfg.norm),
            "layer": _init_layer(km[1], cfg, plan[-1][0], "mlp", dtype),
        }
    return params


# ----------------------------------------------------------------------
# forward passes
# ----------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Token embedding + (VLM) patch-embedding early fusion.

    Returns (x, positions) where positions is (B,S) or (B,S,3) (mrope).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.constrain(params["embed"][tokens], "dp", "tp", None)
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    if cfg.rope_style == "mrope":
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))
        if "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            P = pe.shape[1]
            x = jnp.concatenate([pe, x[:, P:]], axis=1)
            positions = jnp.concatenate(
                [batch["patch_positions"],
                 positions[:, P:]], axis=1)
    return x, positions


def forward(params, cfg: ModelConfig, batch: dict, *, mode: str = "full",
            states: list | None = None, unroll: bool = False,
            remat: bool = False, last_logits_only: bool = False):
    """Full forward. Returns (logits, new_states, aux_loss).

    states: per-layer decode states in plan order (prologue first), or
    None for stateless train forward. remat=True checkpoints each layer
    cycle (the scan body), the standard activation-memory policy.
    """
    plan = layer_plan(cfg)
    k_dense = cfg.moe.first_k_dense if cfg.moe else 0
    period = _period(cfg)
    x, positions = embed_inputs(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    new_states: list = [None] * len(plan)

    apply_layer = (jax.checkpoint(_apply_layer, static_argnums=(1, 2, 3, 6),
                                  prevent_cse=False)
                   if remat else _apply_layer)

    for i, lp in enumerate(params["prologue"]):
        st = None if states is None else states[i]
        x, new_states[i], aux = apply_layer(lp, cfg, *plan[i], x, positions,
                                            mode, st)
        aux_total += aux

    body = plan[k_dense:]
    n_cycles = len(body) // period
    if unroll or n_cycles == 1:
        for c in range(n_cycles):
            for s in range(period):
                li = k_dense + c * period + s
                lp = jax.tree.map(lambda a: a[c], params["stack"][s])
                st = None if states is None else states[li]
                x, new_states[li], aux = apply_layer(
                    lp, cfg, *body[s], x, positions, mode, st)
                aux_total += aux
    else:
        # scan over cycles; per-slot stacked params (and states) are xs
        if states is None:
            st_stacks = None
        else:
            st_stacks = [
                _stack([states[k_dense + c * period + s]
                        for c in range(n_cycles)]) for s in range(period)]

        has_states = states is not None

        def body_fn(carry, xs):
            x, aux_c = carry
            slot_params, slot_states = xs
            outs = []
            for s in range(period):
                st = slot_states[s] if has_states else None
                x, st_new, aux = apply_layer(slot_params[s], cfg, *body[s],
                                             x, positions, mode, st)
                outs.append(st_new if st_new is not None else ())
                aux_c = aux_c + aux
            return (x, aux_c), outs

        xs = (params["stack"],
              st_stacks if st_stacks is not None else [()] * period)
        (x, aux_total), st_out = lax.scan(
            body_fn, (x, aux_total), xs)
        if states is not None:
            for s in range(period):
                for c in range(n_cycles):
                    new_states[k_dense + c * period + s] = jax.tree.map(
                        lambda a: a[c], st_out[s])

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    if last_logits_only:
        # serving prefill: only the last position feeds sampling — skip
        # the (B, S, V) logit materialization entirely.
        x = x[:, -1:]
    logits = unembed(params, cfg, x)
    return logits, new_states, aux_total


def unembed(params, cfg: ModelConfig, x):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w).astype(jnp.float32)
    if logits.ndim == 3:
        logits = L.constrain(logits, "dp", None, "tp")
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def mtp_logits(params, cfg: ModelConfig, hidden, batch):
    """DeepSeek-V3 multi-token prediction head (depth 1): predict t+2
    from [h_t ; embed(token_{t+1})]."""
    mtp = params["mtp"]
    tokens = batch["tokens"]
    emb_next = params["embed"][tokens[:, 1:]]
    h = hidden[:, :-1]
    h2 = L.dense(mtp["proj"], jnp.concatenate([
        L.apply_norm(mtp["norm"], h, cfg.norm), emb_next], -1))
    B, S1 = tokens.shape[0], tokens.shape[1] - 1
    positions = jnp.broadcast_to(jnp.arange(S1, dtype=jnp.int32)[None],
                                 (B, S1))
    plan = layer_plan(cfg)
    h2, _, _ = _apply_layer(mtp["layer"], cfg, plan[-1][0], "mlp",
                            h2, positions, "full", None)
    return unembed(params, cfg, h2)


def forward_hidden(params, cfg: ModelConfig, batch: dict, *,
                   remat: bool = False, unroll: bool = False):
    """Like forward() but also returns pre-unembed hidden states (for MTP)."""
    plan = layer_plan(cfg)
    k_dense = cfg.moe.first_k_dense if cfg.moe else 0
    period = _period(cfg)
    x, positions = embed_inputs(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    apply_layer = (jax.checkpoint(_apply_layer, static_argnums=(1, 2, 3, 6),
                                  prevent_cse=False)
                   if remat else _apply_layer)
    for i, lp in enumerate(params["prologue"]):
        x, _, aux = apply_layer(lp, cfg, *plan[i], x, positions, "full", None)
        aux_total += aux
    body = plan[k_dense:]
    n_cycles = len(body) // period

    if unroll:
        for c in range(n_cycles):
            for s in range(period):
                lp = jax.tree.map(lambda a: a[c], params["stack"][s])
                x, _, aux = apply_layer(lp, cfg, *body[s], x, positions,
                                        "full", None)
                aux_total += aux
    else:
        def body_fn(carry, slot_params):
            x, aux_c = carry
            for s in range(period):
                x, _, aux = apply_layer(slot_params[s], cfg, *body[s],
                                        x, positions, "full", None)
                aux_c = aux_c + aux
            return (x, aux_c), ()

        (x, aux_total), _ = lax.scan(body_fn, (x, aux_total), params["stack"])
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    return unembed(params, cfg, x), x, aux_total


# ----------------------------------------------------------------------
# decode states
# ----------------------------------------------------------------------

def init_states(cfg: ModelConfig, B: int, max_len: int,
                dtype=jnp.bfloat16) -> list:
    """Per-layer decode state in plan order."""
    plan = layer_plan(cfg)
    states = []
    window = cfg.sliding_window
    for mixer, _ in plan:
        if mixer == "attn":
            states.append(L.init_attn_cache(cfg, B, max_len, window=window,
                                            dtype=dtype))
        elif mixer == "attn_local":
            w = cfg.rglru.local_window if cfg.rglru else cfg.sliding_window
            states.append(L.init_attn_cache(cfg, B, max_len, window=w,
                                            dtype=dtype))
        elif mixer == "mla":
            states.append(L.init_mla_cache(cfg, B, max_len, dtype=dtype))
        elif mixer == "rglru":
            states.append(L.init_rglru_state(cfg, B, dtype=dtype))
        elif mixer == "ssd":
            states.append(L.init_ssd_state(cfg, B, dtype=dtype))
        else:
            raise ValueError(mixer)
    return states
