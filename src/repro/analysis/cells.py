"""The exchange-cell matrix and per-cell compile context.

ONE place defines which (algorithm x exchange spec) cells exist — the
36-cell transport x codec x mode matrix plus the regime and backend
cells — consumed by both ``benchmarks/bench_drivers.py`` (convergence +
byte gates) and the ``python -m repro.analysis`` linter (rule sweep).
Growing the matrix here grows both.

:func:`compile_cell` builds the cell's trainer on the smoke-scale
problem, compiles the sharded round AOT, lifts the optimized HLO into a
:class:`repro.analysis.graph.CollectiveGraph`, and returns a
:class:`CellContext` — everything a lint rule needs.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.analysis.graph import CollectiveGraph, lift_hlo
from repro.analysis.traffic import CODEC_WIRE_DTYPE  # noqa: F401 (re-export)
from repro.core.distributed import EXCHANGE_MODES, ExchangeConfig

# every transport x codec cell: the exact transports compose only with
# the f32 identity (validated by CommScheme), `compressed` with all
# three codecs — bare "compressed" (the :int8 alias) is covered by the
# codec-regression test in tests/test_distributed.py
SCHEMES = ("persistent", "spark_faithful", "compressed:f32",
           "compressed:int8", "compressed:int4", "reduce_scatter")
MODES = EXCHANGE_MODES
ALGORITHMS = ("cocoa", "minibatch_scd", "minibatch_sgd")

# Regime cells (full ExchangeConfig specs) on top of the matrix:
# straggler jitter (time-only by assertion), bounded staleness k=2, and
# elastic membership (drop:w@d-r — live-round traffic shrinks with the
# live count while the compiled HLO is membership-invariant).
REGIME_CELLS = (
    ("cocoa", "persistent/straggler:mix(p=0.25,slow=8)"),
    ("cocoa", "persistent/stale:k=2"),
    ("cocoa", "persistent/drop:1@2-4"),
    ("minibatch_sgd", "compressed:int8/drop:1@2-4"),
)

# Codec cells beyond the matrix: the int2/topk base codecs and the
# stateful ef: wrapper (which widens the drivers' local slot with the
# per-worker residual) on every algorithm, plus ef: composed with the
# staleness and elastic-membership regimes and the ring backend — the
# compositions whose codec-state threading is easiest to get wrong.
# topk keeps r=0.125 at smoke scale: k = ceil(0.125*96) = 12 of the
# m = 96 entries, a ratio that actually converges on a 96-vector where
# the 1% default would keep a single coordinate.
CODEC_CELLS = (
    ("cocoa", "compressed:int2"),
    ("cocoa", "compressed:topk(r=0.125)"),
    ("cocoa", "compressed:ef:int4"),
    ("cocoa", "compressed:ef:int2"),
    ("cocoa", "compressed:ef:topk(r=0.125)"),
    ("minibatch_scd", "compressed:ef:int4"),
    ("minibatch_sgd", "compressed:ef:int4"),
    ("cocoa", "compressed:ef:int4/stale:k=2"),
    ("cocoa", "compressed:ef:int4/drop:1@2-4"),
    ("cocoa", "compressed:ef:int4/ring"),
)

# Collective-backend cells: every transport on the explicit ppermute
# ring, plus a stale ring (ring bytes are mode-independent like every
# other transport's).
BACKEND_CELLS = (
    ("cocoa", "persistent/ring"),
    ("cocoa", "compressed:int4/ring"),
    ("minibatch_scd", "reduce_scatter/ring"),
    ("minibatch_sgd", "spark_faithful/ring"),
    ("cocoa", "persistent/ring/stale:k=2"),
)

# The smoke-scale problem every analysis cell compiles against —
# mirrors benchmarks/common.py's smoke tier (m=96, n=256, K=4).
PROBLEM = {"m": 96, "n": 256, "K": 4, "density": 0.2, "zipf_a": 1.1,
           "lam": 1.0, "sgd_step": 0.1, "data_seed": 42,
           "trainer_seed": 0}


@dataclass(frozen=True)
class Cell:
    """One analyzable (algorithm, full exchange spec) point."""
    algorithm: str
    spec: str

    @property
    def id(self) -> str:
        return f"{self.algorithm}={self.spec}"


def matrix_cells() -> tuple[Cell, ...]:
    """The 36-cell algorithm x (transport x codec) x mode matrix."""
    out = []
    for algo in ALGORITHMS:
        for scheme in SCHEMES:
            for mode in MODES:
                spec = scheme if mode == "sync" else f"{scheme}/{mode}"
                out.append(Cell(algo, spec))
    return tuple(out)


def regime_cells() -> tuple[Cell, ...]:
    return tuple(Cell(a, s) for a, s in REGIME_CELLS)


def backend_cells() -> tuple[Cell, ...]:
    return tuple(Cell(a, s) for a, s in BACKEND_CELLS)


def codec_cells() -> tuple[Cell, ...]:
    return tuple(Cell(a, s) for a, s in CODEC_CELLS)


def all_cells() -> tuple[Cell, ...]:
    return (matrix_cells() + regime_cells() + backend_cells()
            + codec_cells())


def resolve_cells(selector: str) -> tuple[Cell, ...]:
    """CLI cell selector: ``all`` | ``matrix`` | ``regime`` | ``backend``
    or ``codec``, or a comma-separated list of ``algo=spec`` entries."""
    named = {"all": all_cells, "matrix": matrix_cells,
             "regime": regime_cells, "backend": backend_cells,
             "codec": codec_cells}
    if selector in named:
        return named[selector]()
    out = []
    for entry in selector.split(","):
        algo, _, spec = entry.partition("=")
        if not spec or algo not in ALGORITHMS:
            raise ValueError(
                f"bad cell {entry!r}: expected algo=spec with algo in "
                f"{ALGORITHMS} (or one of {sorted(named)})")
        ExchangeConfig.parse(spec)  # validate early
        out.append(Cell(algo, spec))
    return tuple(out)


_PROBLEM_CACHE: dict = {}


def problem():
    """(A, b) for the smoke-scale analysis problem (cached)."""
    from repro.data import make_glm_data
    key = "smoke"
    if key not in _PROBLEM_CACHE:
        p = PROBLEM
        A, b, _ = make_glm_data(m=p["m"], n=p["n"], density=p["density"],
                                zipf_a=p["zipf_a"], seed=p["data_seed"])
        _PROBLEM_CACHE[key] = (A, b)
    return _PROBLEM_CACHE[key]


def build_trainer(cell: Cell, K: int | None = None):
    """The cell's trainer on the smoke problem (same construction as
    bench_drivers' `_make_trainer`, minus the tier plumbing)."""
    from repro.core import (CoCoAConfig, CoCoATrainer, MinibatchSCD,
                            MinibatchSGD, SGDConfig)
    p = PROBLEM
    K = K or p["K"]
    A, b = problem()
    if cell.algorithm == "minibatch_sgd":
        return MinibatchSGD(
            SGDConfig(batch_frac=1.0, step_size=p["sgd_step"], lam=p["lam"],
                      K=K, seed=p["trainer_seed"], exchange=cell.spec), A, b)
    n_local = -(p["n"] // -K)
    cfg = CoCoAConfig(K=K, H=n_local, lam=p["lam"], solver="scd_ref",
                      exchange=cell.spec, seed=p["trainer_seed"])
    cls = MinibatchSCD if cell.algorithm == "minibatch_scd" \
        else CoCoATrainer
    return cls(cfg, A, b)


@dataclass
class CellContext:
    """Everything a cell-scoped lint rule gets to look at."""
    cell: Cell
    trainer: object
    round_fn: object
    hlo_text: str
    graph: CollectiveGraph
    K: int
    exchange: object            # resolved ExchangeConfig
    update_len: int             # the exchanged update-vector length
    mesh: object = None
    extra: dict = field(default_factory=dict)

    @property
    def id(self) -> str:
        return self.cell.id

    def compile_variant(self, spec: str) -> "CellContext":
        """Compile a sibling cell (same algorithm/mesh, different spec) —
        used by membership-invariant to compare against full membership."""
        return compile_cell(replace(self.cell, spec=spec), mesh=self.mesh)


def lower_round_hlo(trainer, round_fn) -> str:
    """Optimized HLO text of the sharded round (AOT — does not populate
    the jit call cache, so single-compile still sees a cold function)."""
    import jax
    local, shared = trainer.init_state()
    return round_fn.jitted.lower(
        round_fn.split_keys(jax.random.key(0)), local, shared,
        1).compile().as_text()


def compile_cell(cell: Cell, mesh=None, K: int | None = None
                 ) -> CellContext:
    """Build + AOT-compile one cell and lift its collective graph."""
    import jax

    from repro.utils.compat import make_mesh

    if mesh is None:
        K = K or min(PROBLEM["K"], len(jax.devices()))
        mesh = make_mesh((K,), ("workers",))
    K = mesh.devices.size
    tr = build_trainer(cell, K=K)
    round_fn = tr.build_sharded_round(mesh)
    hlo = lower_round_hlo(tr, round_fn)
    # the exchanged update vector: SGD averages the n-length gradient,
    # the CoCoA family exchanges the m-length shared vector
    update_len = tr.n if cell.algorithm == "minibatch_sgd" else tr.m
    return CellContext(cell=cell, trainer=tr, round_fn=round_fn,
                       hlo_text=hlo, graph=lift_hlo(hlo), K=K,
                       exchange=tr.exchange, update_len=update_len,
                       mesh=mesh)
