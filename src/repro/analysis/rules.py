"""The cell-scoped lint rules over compiled collective graphs.

Each rule receives a :class:`repro.analysis.cells.CellContext` and
returns a (possibly empty) list of findings. Severities:

- ``error`` — a correctness/accounting invariant the CI gate fails on;
- ``warning`` — a known inefficiency worth surfacing (e.g. the f32 HBM
  intermediate on the codec gather side named in ROADMAP).

Importing this module populates the registry in
:mod:`repro.analysis.findings`.
"""
from __future__ import annotations

from repro.analysis.findings import finding, register_rule
from repro.analysis.traffic import (QUANTIZED_DTYPES, codec_wire_dtype,
                                    derived_round_traffic, padded_len,
                                    quantized_wire_dtypes)

FP_BYTES = 4                 # the exchanged update is f32
SCALE_BYTES = 4              # one f32 absmax scale per worker payload


@register_rule("bytes-match", "error")
def rule_bytes_match(ctx):
    """Modelled comm_bytes_per_round equals bytes derived from the HLO
    collectives (the paper's modelled-vs-actual gap, asserted to zero)."""
    out = []
    if ctx.K < 2:
        return out
    modelled = ctx.trainer.comm_bytes_per_round()
    derived = derived_round_traffic(ctx.graph, ctx.exchange, ctx.K)
    if modelled != derived:
        out.append(finding(
            "bytes-match", ctx.id,
            f"modelled comm_bytes_per_round {modelled} != {derived} "
            f"derived from the HLO collectives (K={ctx.K})"))
    # reduce-scatter padding cross-check against the ONE padded_len
    # owner (repro.comm.collectives): the compiled rs operand must be
    # the K-padded update vector
    if (ctx.exchange.scheme.transport == "reduce_scatter"
            and ctx.exchange.backend == "xla"):
        rs_bytes = sum(op.operand_bytes
                       for op in ctx.graph.ops("reduce-scatter"))
        expect = padded_len(ctx.update_len, ctx.K) * FP_BYTES
        if rs_bytes != expect:
            out.append(finding(
                "bytes-match", ctx.id,
                f"reduce-scatter operand is {rs_bytes} bytes; "
                f"padded_len({ctx.update_len}, {ctx.K}) models "
                f"{expect}"))
    return out


@register_rule("wire-dtype", "error")
def rule_wire_dtype(ctx):
    """Codec cells ship only their quantized dtype on the wire (s8 for
    int8, packed u8 for int4/int2, the same through the ef: wrapper) —
    no f32 payload escapes. topk legitimately ships f32 values, so it
    expects (and must show) no quantized dtype."""
    out = []
    if ctx.K < 2:
        return out
    codec = ctx.exchange.scheme.codec.name
    expect_dt = codec_wire_dtype(codec)
    seen = quantized_wire_dtypes(ctx.graph)
    expect = {expect_dt} if expect_dt else set()
    if seen != expect:
        out.append(finding(
            "wire-dtype", ctx.id,
            f"quantized collective dtypes {sorted(seen) or '{}'} do not "
            f"match codec {codec!r} (expected {sorted(expect) or '{}'})"))
    if expect_dt:
        # a quantizing codec may move f32 only as per-worker scales
        for op in ctx.graph.collectives:
            if op.kind not in ("all-gather", "collective-permute"):
                continue
            fat = [s for s in op.operand_shapes
                   if s.dtype == "f32" and s.bytes > SCALE_BYTES]
            for s in fat:
                out.append(finding(
                    "wire-dtype", ctx.id,
                    f"{op.kind} {op.name} ships f32{list(s.dims)} "
                    f"({s.bytes} bytes) under the {codec} codec — "
                    f"f32 payload escaped to the wire"))
    return out


def _is_single_ring(pairs, K: int) -> bool:
    if pairs is None or len(pairs) != K:
        return False
    nxt = dict(pairs)
    if len(nxt) != K or set(nxt) != set(range(K)) \
            or set(nxt.values()) != set(range(K)):
        return False
    # follow the permutation from 0: must return to 0 in exactly K hops
    seen, cur = 0, 0
    while True:
        cur = nxt[cur]
        seen += 1
        if cur == 0:
            return seen == K
        if seen > K:
            return False


@register_rule("ring-topology", "error")
def rule_ring_topology(ctx):
    """Every ring-backend collective-permute's source-target pairs form
    one closed K-ring (the deadlock/ordering invariant per hop)."""
    out = []
    if ctx.exchange.backend != "ring" or ctx.K < 2:
        return out
    cps = ctx.graph.ops("collective-permute")
    if not cps:
        out.append(finding(
            "ring-topology", ctx.id,
            "ring backend compiled to no collective-permute ops"))
        return out
    for op in cps:
        if not _is_single_ring(op.source_target_pairs, ctx.K):
            out.append(finding(
                "ring-topology", ctx.id,
                f"collective-permute {op.name} pairs "
                f"{op.source_target_pairs} are not a single closed "
                f"{ctx.K}-ring"))
    return out


@register_rule("membership-invariant", "error")
def rule_membership_invariant(ctx):
    """Elastic drop: cells compile to the identical collective set as
    full membership — one compile serves all rounds."""
    if ctx.exchange.membership.empty or ctx.K < 2:
        return []
    import dataclasses

    from repro.core.distributed import MembershipSchedule
    full_spec = dataclasses.replace(
        ctx.exchange, membership=MembershipSchedule()).spec
    vctx = ctx.compile_variant(full_spec)
    if ctx.graph.signature() != vctx.graph.signature():
        return [finding(
            "membership-invariant", ctx.id,
            f"collective set differs from full membership "
            f"({full_spec!r}): membership masking leaked into the "
            f"compiled collectives")]
    return []


@register_rule("f32-intermediate", "error")
def rule_f32_intermediate(ctx):
    """f32 HBM tensors materialized between a codec decode and its
    mean/apply — the gather-side dequantize inefficiency closed by the
    fused decode+reduce path (``repro.kernels.dequant`` on TPU, the
    sequential oracle elsewhere), promoted from warning to error now
    that every quantizing cell compiles clean."""
    codec = ctx.exchange.scheme.codec.name
    if not codec_wire_dtype(codec) or ctx.K < 2:
        return []
    names = [op.name for op in ctx.graph.collectives
             if op.kind in ("all-gather", "collective-permute")
             and any(dt in QUANTIZED_DTYPES for dt in op.operand_dtypes)]
    # a decode that materializes the full K-stacked f32 update before
    # reducing burns K x update_len x 4 bytes of HBM per round; tuple /
    # get-tuple-element only forward existing buffers (their result
    # shapes restate every component), so they can't be the
    # materialization site
    threshold = ctx.K * ctx.update_len * FP_BYTES
    fat = [i for i in ctx.graph.downstream(names, depth=4)
           if i.op not in ("tuple", "get-tuple-element")
           and sum(s.bytes for s in i.result_shapes
                   if s.dtype == "f32") >= threshold]
    if fat:
        worst = max(fat, key=lambda i: i.result_bytes)
        return [finding(
            "f32-intermediate", ctx.id,
            f"{len(fat)} f32 intermediate(s) >= {threshold} bytes "
            f"within 4 ops of the decoded payload (e.g. {worst.op} "
            f"{worst.name}: {worst.result_bytes} bytes) — fuse "
            f"decode+reduce to skip the stacked f32 HBM roundtrip")]
    return []


@register_rule("single-compile", "error")
def rule_single_compile(ctx):
    """A driver run triggers exactly one jit trace of the round function
    (recompiles would hide in wall-clock, not in bytes)."""
    import jax

    from repro.core.distributed import place_state

    jitted = ctx.round_fn.jitted
    if not hasattr(jitted, "_cache_size"):
        return [finding(
            "single-compile", ctx.id,
            "jit cache-size hook unavailable on this jax version — "
            "compile count not checked")]
    local, shared = place_state(ctx.round_fn.mesh, *ctx.trainer.init_state())
    key = jax.random.key(0)
    # rounds 1-2 are the placement warmup: round 1 sees freshly
    # device_put state (explicit NamedShardings), round 2 sees the jit's
    # own output shardings — one extra cache entry there is expected,
    # and from round 3 on every round must reuse the steady-state trace
    warmup = 0
    for t in (1, 2, 3, 4, 5):
        key, sub = jax.random.split(key)
        local, shared, metric = ctx.round_fn(local, shared, sub, t)
        if t == 2:
            warmup = jitted._cache_size()
    jax.block_until_ready(metric)
    out = []
    retraces = jitted._cache_size() - warmup
    if retraces:
        out.append(finding(
            "single-compile", ctx.id,
            f"steady-state rounds retraced the round function "
            f"{retraces} time(s) after warmup — a per-round value is "
            f"being treated as static"))
    if warmup > 2:
        out.append(finding(
            "single-compile", ctx.id,
            f"the first two driver rounds triggered {warmup} jit "
            f"traces (expected 1, plus at most 1 placement-warmup "
            f"entry)"))
    return out
