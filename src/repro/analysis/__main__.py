"""Entry point: ``python -m repro.analysis``."""
import sys

from repro.analysis.run import main

sys.exit(main())
