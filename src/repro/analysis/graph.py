"""Lift optimized HLO text into a structured collective graph.

``compiled.as_text()`` (post-SPMD-partitioning HLO) is the only place
the GSPMD-inserted collectives are visible.  This module parses that
text into per-op records — opcode, operand/result dtypes and byte
sizes, replica groups, channel ids, source-target pairs — plus a full
instruction symbol table for dataflow queries (who consumes a
collective's result).  It supersedes the aggregate-only
``CollectiveStats`` in :mod:`repro.utils.hlo`, which now delegates
here.

Parser notes (each pinned by the corpus under ``tests/data/hlo/``):

- dtype widths are in **bits** so the packed 4-bit types (``s4``/``u4``)
  size correctly (a byte table silently counted them as 0);
- modern HLO prints operand types inline
  (``all-gather(s8[1,96]{1,0} %fusion)``) — those are preferred, with a
  two-pass symbol-table fallback for operands spelled as bare ``%refs``;
- async ``-start``/``-done`` pairs count ONCE (at the ``-start``), and a
  start op's tuple result drops the leading operand-alias elements so
  result bytes reflect the gathered output, not operand+output;
- tuple result types are scanned with a balanced-paren walk, so layouts
  containing parens (``{0:T(256)}``) cannot truncate the tuple.

This module is intentionally pure (re + dataclasses only): it is
imported by ``repro.utils.hlo`` at package-import time and must not
drag in jax or the rest of ``repro``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import reduce

# Bit widths per HLO dtype. 4-bit types are genuine sub-byte dtypes:
# byte counts round up per *shape*, not per element (s4[96] = 48 bytes).
DTYPE_BITS = {
    "pred": 8, "s2": 2, "u2": 2, "s4": 4, "u4": 4,
    "s8": 8, "u8": 8,
    "f8e4m3fn": 8, "f8e5m2": 8, "f8e4m3": 8, "f8e3m4": 8,
    "f8e4m3b11fnuz": 8, "f8e5m2fnuz": 8, "f8e4m3fnuz": 8, "f8e8m0fnu": 8,
    "s16": 16, "u16": 16, "f16": 16, "bf16": 16,
    "s32": 32, "u32": 32, "f32": 32,
    "s64": 64, "u64": 64, "f64": 64, "c64": 64, "c128": 128,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.*)$")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[\d,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{((?:\{[\d, ]*\},?)*)\}")
_GROUP_RE = re.compile(r"\{([\d, ]*)\}")
_IOTA_RE = re.compile(
    r"replica_groups=\[(?P<lhs>[\d,]+)\]<=\[(?P<dims>[\d,]+)\]"
    r"(?:T\((?P<perm>[\d,]+)\))?")


@dataclass(frozen=True)
class Shape:
    """One array shape: dtype, dims, and its padded byte size."""
    dtype: str
    dims: tuple[int, ...]

    @property
    def elems(self) -> int:
        return reduce(lambda a, b: a * b, self.dims, 1)

    @property
    def bytes(self) -> int:
        return (self.elems * DTYPE_BITS[self.dtype] + 7) // 8


def parse_shapes(type_str: str) -> tuple[Shape, ...]:
    """All array shapes in an HLO type string (tuples flattened)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in DTYPE_BITS:
            continue
        dims = m.group("dims")
        out.append(Shape(dt, tuple(int(d) for d in dims.split(","))
                         if dims else ()))
    return tuple(out)


def _shapes_bytes(shapes: tuple[Shape, ...]) -> int:
    return sum(s.bytes for s in shapes)


@dataclass(frozen=True)
class Instruction:
    """Symbol-table entry: every parsed HLO instruction."""
    name: str
    op: str
    result_shapes: tuple[Shape, ...]
    operand_names: tuple[str, ...]

    @property
    def result_bytes(self) -> int:
        return _shapes_bytes(self.result_shapes)


@dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction with its wire-relevant attributes."""
    kind: str                      # base opcode, e.g. "all-gather"
    name: str
    operand_names: tuple[str, ...]
    operand_shapes: tuple[Shape, ...]
    result_shapes: tuple[Shape, ...]
    replica_groups: tuple[tuple[int, ...], ...] | None
    channel_id: int | None
    source_target_pairs: tuple[tuple[int, int], ...] | None
    asynchronous: bool = False     # was a -start op

    @property
    def operand_bytes(self) -> int:
        return _shapes_bytes(self.operand_shapes)

    @property
    def result_bytes(self) -> int:
        return _shapes_bytes(self.result_shapes)

    @property
    def operand_dtypes(self) -> tuple[str, ...]:
        return tuple(s.dtype for s in self.operand_shapes)

    @property
    def result_dtypes(self) -> tuple[str, ...]:
        return tuple(s.dtype for s in self.result_shapes)

    def signature(self) -> tuple:
        """Structural identity ignoring instruction names/channel ids —
        what the membership-invariant rule compares across compiles.
        Plain nested tuples so signatures sort/compare reliably."""
        return (self.kind,
                tuple((s.dtype, s.dims) for s in self.operand_shapes),
                tuple((s.dtype, s.dims) for s in self.result_shapes),
                self.replica_groups or (),
                self.source_target_pairs or ())


def _scan_balanced(s: str, start: int) -> tuple[str, int]:
    """Content between s[start]=='(' and its match; returns (inner, end)
    with end just past the closing paren."""
    assert s[start] == "("
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return s[start + 1:i], i + 1
    return s[start + 1:], len(s)


def _split_top(s: str) -> list[str]:
    """Split on top-level commas (commas inside (), {}, [] don't count)."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _iota_replica_groups(lhs: tuple[int, ...], dims: tuple[int, ...],
                         perm: tuple[int, ...] | None
                         ) -> tuple[tuple[int, ...], ...]:
    """Expand the iota replica-group form ``[g,s]<=[dims](T(perm))``."""
    n = reduce(lambda a, b: a * b, dims, 1)
    ids = list(range(n))
    if perm:
        # reshape iota(n) to dims, transpose by perm, flatten
        strides = [0] * len(dims)
        acc = 1
        for i in range(len(dims) - 1, -1, -1):
            strides[i] = acc
            acc *= dims[i]
        pdims = [dims[p] for p in perm]
        pstrides = [strides[p] for p in perm]
        out = []
        idx = [0] * len(pdims)
        for _ in range(n):
            out.append(sum(i * s for i, s in zip(idx, pstrides)))
            for ax in range(len(pdims) - 1, -1, -1):
                idx[ax] += 1
                if idx[ax] < pdims[ax]:
                    break
                idx[ax] = 0
        ids = out
    groups, size = lhs[0], reduce(lambda a, b: a * b, lhs[1:], 1)
    return tuple(tuple(ids[g * size:(g + 1) * size])
                 for g in range(groups))


def _parse_attrs(attrs: str):
    channel = None
    m = _CHANNEL_RE.search(attrs)
    if m:
        channel = int(m.group(1))
    pairs = None
    m = _PAIRS_RE.search(attrs)
    if m:
        pairs = tuple((int(a), int(b))
                      for a, b in _PAIR_RE.findall(m.group(1)))
    groups = None
    m = _GROUPS_RE.search(attrs)
    if m:
        groups = tuple(
            tuple(int(x) for x in g.replace(" ", "").split(",") if x)
            for g in _GROUP_RE.findall(m.group(1)))
    else:
        m = _IOTA_RE.search(attrs)
        if m:
            lhs = tuple(int(x) for x in m.group("lhs").split(","))
            dims = tuple(int(x) for x in m.group("dims").split(","))
            perm = (tuple(int(x) for x in m.group("perm").split(","))
                    if m.group("perm") else None)
            groups = _iota_replica_groups(lhs, dims, perm)
    return channel, pairs, groups


def _async_result(operand_shapes: tuple[Shape, ...],
                  result_shapes: tuple[Shape, ...]) -> tuple[Shape, ...]:
    """A ``-start`` op's tuple result aliases its operands in the leading
    elements; the true collective output is the remainder. Counting the
    whole tuple double-counts the operand into result bytes."""
    k = len(operand_shapes)
    if len(result_shapes) > k and result_shapes[:k] == operand_shapes:
        return result_shapes[k:]
    return result_shapes


def lift_hlo(hlo_text: str) -> "CollectiveGraph":
    """Parse optimized HLO text into a :class:`CollectiveGraph`."""
    instructions: dict[str, Instruction] = {}
    # (name, base_kind, async, operand segs, result shapes, attrs)
    pending: list[tuple] = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, rest = m.group("name"), m.group("rest")
        # result type: balanced tuple or a single space-free token
        if rest.startswith("("):
            inner, idx = _scan_balanced(rest, 0)
            type_str = "(" + inner + ")"
        else:
            idx = rest.find(" ")
            if idx < 0:
                continue
            type_str = rest[:idx]
        tail = rest[idx:].lstrip()
        om = re.match(r"([\w\-]+)\(", tail)
        if not om:
            continue
        op = om.group(1)
        operand_str, end = _scan_balanced(tail, om.end() - 1)
        attrs = tail[end:]
        result_shapes = parse_shapes(type_str)
        operand_names = tuple(_NAME_RE.findall(operand_str))
        instructions[name] = Instruction(name, op, result_shapes,
                                         operand_names)
        base = op
        is_async = False
        for sfx in ("-start", "-done"):
            if op.endswith(sfx):
                base = op[:-len(sfx)]
                is_async = True
        if base not in COLLECTIVE_OPS or op.endswith("-done"):
            continue  # -done pairs with its -start: count once
        pending.append((name, base, is_async, _split_top(operand_str),
                        result_shapes, attrs))
    # Second pass: resolve operand shapes — inline types preferred,
    # symbol table for bare %refs (synthetic/older HLO spellings).
    collectives = []
    for name, kind, is_async, segs, result_shapes, attrs in pending:
        op_names, op_shapes = [], []
        for seg in segs:
            nm = _NAME_RE.search(seg)
            if nm:
                op_names.append(nm.group(1))
            inline = parse_shapes(seg)
            if inline:
                op_shapes.extend(inline)
            elif nm and nm.group(1) in instructions:
                op_shapes.extend(instructions[nm.group(1)].result_shapes)
        operand_shapes = tuple(op_shapes)
        if is_async:
            result_shapes = _async_result(operand_shapes, result_shapes)
        channel, pairs, groups = _parse_attrs(attrs)
        collectives.append(CollectiveOp(
            kind=kind, name=name, operand_names=tuple(op_names),
            operand_shapes=operand_shapes, result_shapes=result_shapes,
            replica_groups=groups, channel_id=channel,
            source_target_pairs=pairs, asynchronous=is_async))
    return CollectiveGraph(tuple(collectives), instructions)


@dataclass
class CollectiveGraph:
    """All collectives in one HLO module, plus the full symbol table."""
    collectives: tuple[CollectiveOp, ...]
    instructions: dict[str, Instruction] = field(default_factory=dict)

    def ops(self, kind: str | None = None) -> tuple[CollectiveOp, ...]:
        if kind is None:
            return self.collectives
        return tuple(op for op in self.collectives if op.kind == kind)

    def by_kind(self) -> dict:
        """kind -> (count, operand bytes, result bytes) — the aggregate
        view ``CollectiveStats`` used to be."""
        out: dict[str, tuple[int, int, int]] = {}
        for op in self.collectives:
            c, ob, rb = out.get(op.kind, (0, 0, 0))
            out[op.kind] = (c + 1, ob + op.operand_bytes,
                            rb + op.result_bytes)
        return out

    @property
    def total_operand_bytes(self) -> int:
        return sum(op.operand_bytes for op in self.collectives)

    @property
    def total_result_bytes(self) -> int:
        return sum(op.result_bytes for op in self.collectives)

    @property
    def total_count(self) -> int:
        return len(self.collectives)

    def signature(self) -> tuple:
        """Order-insensitive structural identity of the collective set
        (names and channel ids ignored — they vary across compiles)."""
        return tuple(sorted(op.signature() for op in self.collectives))

    def consumers(self) -> dict[str, tuple[Instruction, ...]]:
        """instruction name -> instructions that take it as an operand."""
        out: dict[str, list[Instruction]] = {}
        for instr in self.instructions.values():
            for ref in instr.operand_names:
                out.setdefault(ref, []).append(instr)
        return {k: tuple(v) for k, v in out.items()}

    def downstream(self, names, depth: int = 3) -> tuple[Instruction, ...]:
        """Instructions reachable from ``names`` within ``depth`` hops of
        the def-use graph (used by the f32-intermediate rule)."""
        cons = self.consumers()
        seen: dict[str, Instruction] = {}
        frontier = list(names)
        for _ in range(depth):
            nxt = []
            for n in frontier:
                for instr in cons.get(n, ()):
                    if instr.name not in seen:
                        seen[instr.name] = instr
                        nxt.append(instr.name)
            frontier = nxt
        return tuple(seen.values())

    def summary(self) -> str:
        lines = []
        for k, (c, ob, rb) in sorted(self.by_kind().items()):
            lines.append(f"{k:20s} n={c:4d} operand={ob / 1e6:10.2f}MB "
                         f"result={rb / 1e6:10.2f}MB")
        lines.append(f"{'TOTAL':20s} n={self.total_count:4d} "
                     f"operand={self.total_operand_bytes / 1e6:10.2f}MB "
                     f"result={self.total_result_bytes / 1e6:10.2f}MB")
        return "\n".join(lines)
