"""Typed findings and the lint-rule registry.

A rule is a named check with a fixed severity and scope:

- ``cell`` rules run once per compiled exchange cell and receive a
  :class:`repro.analysis.cells.CellContext`;
- ``source`` rules run once per analysis sweep over the repo's Python
  source tree and receive a root path.

Rules are registered by importing the module that defines them
(:mod:`repro.analysis.rules`, :mod:`repro.analysis.pylint_jax`); the
registry itself lives here so that registration has no import cost
beyond dataclasses.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

SEVERITIES = ("error", "warning", "info")
SCOPES = ("cell", "source")


@dataclass(frozen=True)
class Finding:
    """One machine-readable lint finding."""
    rule: str       # rule id, e.g. "bytes-match"
    severity: str   # "error" | "warning" | "info"
    cell: str       # "algo=spec" for cell rules, "path:line" for source
    message: str

    def to_json(self) -> dict:
        return asdict(self)


@dataclass(frozen=True)
class Rule:
    """A registered check. ``check`` returns a list of findings; an
    empty list means the rule passed (or did not apply)."""
    id: str
    severity: str
    scope: str
    doc: str
    check: callable

    def to_json(self) -> dict:
        return {"id": self.id, "severity": self.severity,
                "scope": self.scope, "doc": self.doc}


RULES: dict[str, Rule] = {}


def register_rule(rule_id: str, severity: str, scope: str = "cell"):
    """Decorator: register ``fn`` as rule ``rule_id``. The function's
    first docstring line becomes the rule's one-line description."""
    assert severity in SEVERITIES, severity
    assert scope in SCOPES, scope

    def deco(fn):
        doc = (fn.__doc__ or "").strip().splitlines()[0] if fn.__doc__ \
            else ""
        assert rule_id not in RULES, f"duplicate rule id {rule_id!r}"
        RULES[rule_id] = Rule(rule_id, severity, scope, doc, fn)
        return fn
    return deco


def finding(rule_id: str, cell: str, message: str) -> Finding:
    """Build a Finding with the registered severity for ``rule_id``."""
    return Finding(rule_id, RULES[rule_id].severity, cell, message)


def max_severity(findings) -> str | None:
    """Worst severity present, or None for an empty list."""
    worst = None
    for f in findings:
        if worst is None or SEVERITIES.index(f.severity) < \
                SEVERITIES.index(worst):
            worst = f.severity
    return worst
