"""`python -m repro.analysis` — sweep the exchange matrix through the
lint-rule registry, print a findings table, write ANALYSIS.json, exit
nonzero on error-severity findings.

Device faking happens here (before jax import) the same way
``repro.bench.run`` does it: the matrix needs a K=4 mesh, so the CLI
appends ``--xla_force_host_platform_device_count`` to XLA_FLAGS unless
jax is already imported with enough devices.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_args(argv=None):
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of compiled exchange cells: lift "
                    "each cell's optimized HLO into a collective graph "
                    "and run the lint-rule registry over it.")
    p.add_argument("--cells", default="all",
                   help="all | matrix | regime | backend | "
                        "comma-separated algo=spec list (default: all)")
    p.add_argument("--out", default="ANALYSIS.json",
                   help="findings JSON path (default: ANALYSIS.json)")
    p.add_argument("--devices", type=int, default=4,
                   help="CPU devices to fake for the worker mesh "
                        "(default: 4, the smoke-matrix K)")
    p.add_argument("--src", default=None,
                   help="source tree for the AST lint rules "
                        "(default: the installed repro package dir)")
    p.add_argument("--no-source-lint", action="store_true",
                   help="skip the source-scoped AST rules")
    p.add_argument("--inject", choices=("wire-f32",), default=None,
                   help="inject a known violation (validates that the "
                        "gate trips): wire-f32 analyzes a full-precision "
                        "compile under an int8-claiming exchange")
    return p.parse_args(argv)


def _fake_devices(n: int) -> None:
    if "jax" in sys.modules:
        import jax
        if len(jax.devices()) < n:
            print(f"warning: jax already imported with "
                  f"{len(jax.devices())} device(s); --devices {n} "
                  f"ignored", file=sys.stderr)
        return
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}").strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def _injected_cell(cells_mod):
    """A deliberately broken cell: the compressed:f32 compile analyzed
    under an exchange that CLAIMS the int8 codec — wire-dtype and
    bytes-match must both fire."""
    import dataclasses

    base = cells_mod.compile_cell(cells_mod.Cell("cocoa", "compressed:f32"))
    claimed = cells_mod.build_trainer(
        cells_mod.Cell("cocoa", "compressed:int8"), K=base.K)
    return dataclasses.replace(
        base,
        cell=cells_mod.Cell("cocoa", "compressed:int8[injected-f32-wire]"),
        trainer=claimed, exchange=claimed.exchange)


def main(argv=None) -> int:
    args = _parse_args(argv)
    _fake_devices(args.devices)

    # heavy imports only after the device fake is in place
    from repro.analysis import cells as cells_mod
    from repro.analysis import pylint_jax, rules  # noqa: F401 (registers)
    from repro.analysis.findings import RULES, SEVERITIES

    try:
        selected = cells_mod.resolve_cells(args.cells)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    findings, analyzed = [], []
    for cell in selected:
        ctx = cells_mod.compile_cell(cell)
        analyzed.append({"cell": cell.id, "K": ctx.K,
                         "collectives": ctx.graph.total_count,
                         "hlo_operand_bytes":
                             ctx.graph.total_operand_bytes})
        for rule in RULES.values():
            if rule.scope == "cell":
                findings.extend(rule.check(ctx))
        print(f"analyzed {cell.id} "
              f"({ctx.graph.total_count} collectives)")
    if args.inject == "wire-f32":
        ctx = _injected_cell(cells_mod)
        analyzed.append({"cell": ctx.id, "K": ctx.K, "injected": True,
                         "collectives": ctx.graph.total_count,
                         "hlo_operand_bytes":
                             ctx.graph.total_operand_bytes})
        for rule in RULES.values():
            if rule.scope == "cell":
                findings.extend(rule.check(ctx))
    if not args.no_source_lint:
        src_root = args.src or os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))
        for rule in RULES.values():
            if rule.scope == "source":
                findings.extend(rule.check(src_root))

    counts = {s: sum(1 for f in findings if f.severity == s)
              for s in SEVERITIES}
    print()
    if findings:
        w = max(len(f.rule) for f in findings)
        for f in sorted(findings,
                        key=lambda f: (SEVERITIES.index(f.severity),
                                       f.rule, f.cell)):
            print(f"{f.severity.upper():7s} {f.rule:{w}s} {f.cell}\n"
                  f"        {f.message}")
    print(f"\n{len(analyzed)} cells analyzed, {len(RULES)} rules: "
          + ", ".join(f"{counts[s]} {s}" for s in SEVERITIES))
    report = {
        "cells": analyzed,
        "rules": [r.to_json() for r in RULES.values()],
        "findings": [f.to_json() for f in findings],
        "summary": {"cells": len(analyzed), **counts},
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"wrote {args.out}")
    return 1 if counts["error"] else 0


if __name__ == "__main__":
    sys.exit(main())
