"""Lightweight AST lint for repo-specific jax footguns.

Two source-scoped rules (warning severity), run by the analysis CLI
over ``src/repro``:

- ``jit-module-array``: a module-level jax array (``W = jnp.zeros(...)``
  or ``jax.device_put(...)``) referenced from inside a jitted function.
  Closing over a module-level array bakes its *placement* into the
  trace — the PR 7 multi-process footgun: under ``jax.distributed`` the
  closed-over constant is addressable on one process only and jit
  refuses (or silently re-commits) it. Pass arrays as arguments.
- ``deprecated-spelling``: call sites still using spellings that raise
  ``ReproDeprecationWarning`` at runtime (``get_scheme()``,
  ``get_mode()``, ``comm_scheme=`` / ``exchange_mode=`` keywords) —
  they warn today and break when the deprecation window closes.

Pure stdlib (ast) — no jax import, so the lint runs anywhere.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.findings import finding, register_rule

# call roots that create/commit a jax array at module scope
_ARRAY_ROOTS = ("jnp", "jax")
# deprecated call-target names (defined — and allowed — only here)
_DEPRECATED_CALLS = ("get_scheme", "get_mode")
_DEF_MODULE = os.path.join("core", "distributed.py")
# deprecated keyword spellings; resolve_exchange/_fold_* own the
# fold-in implementation so their call sites are the one exception
_DEPRECATED_KWARGS = ("comm_scheme", "exchange_mode", "scheme_name")
_KWARG_OK_CALLEES = ("resolve_exchange",)


def _call_root(node: ast.AST) -> str | None:
    """Leftmost Name of a (possibly dotted) call target."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _call_leaf(node: ast.AST) -> str | None:
    """Rightmost attribute / bare name of a call target."""
    if isinstance(node, ast.Attribute):
        return node.attr
    return node.id if isinstance(node, ast.Name) else None


def _is_jit_decorator(dec: ast.AST) -> bool:
    """jax.jit / jit / functools.partial(jax.jit, ...)."""
    if isinstance(dec, ast.Call):
        if _call_leaf(dec.func) == "partial":
            return any(_call_leaf(a) == "jit" for a in dec.args)
        dec = dec.func
    return _call_leaf(dec) == "jit"


def _module_arrays(tree: ast.Module) -> dict[str, int]:
    """name -> lineno of module-level jax-array bindings."""
    out = {}
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = [t for t in node.targets if isinstance(t, ast.Name)]
            value = node.value
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            targets, value = [node.target], node.value
        else:
            continue
        if isinstance(value, ast.Call) and \
                _call_root(value.func) in _ARRAY_ROOTS:
            for t in targets:
                out[t.id] = node.lineno
    return out


def _jitted_functions(tree: ast.Module):
    """All function defs that end up jitted: decorated with jax.jit (or
    partial(jax.jit, ...)), or wrapped later via ``g = jax.jit(f)``."""
    fns = {n.name: n for n in ast.walk(tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    jitted = [f for f in fns.values()
              if any(_is_jit_decorator(d) for d in f.decorator_list)]
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_leaf(node.func) == "jit":
            for a in node.args:
                if isinstance(a, ast.Name) and a.id in fns:
                    jitted.append(fns[a.id])
    return jitted


def _closure_reads(fn, names: dict[str, int]):
    """(name, lineno) reads of ``names`` inside ``fn`` that are not
    shadowed by a parameter or a local binding."""
    args = fn.args
    params = {a.arg for a in (args.posonlyargs + args.args
                              + args.kwonlyargs)}
    if args.vararg:
        params.add(args.vararg.arg)
    if args.kwarg:
        params.add(args.kwarg.arg)
    local = set(params)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            local.add(node.id)
    return [(n.id, n.lineno) for n in ast.walk(fn)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
            and n.id in names and n.id not in local]


def lint_file(path: str, rel: str) -> list:
    with open(path) as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [finding("deprecated-spelling", f"{rel}:{e.lineno or 0}",
                        f"unparseable source: {e.msg}")]
    out = []
    arrays = _module_arrays(tree)
    if arrays:
        for fn in _jitted_functions(tree):
            for name, lineno in _closure_reads(fn, arrays):
                out.append(finding(
                    "jit-module-array", f"{rel}:{lineno}",
                    f"jitted function {fn.name!r} closes over "
                    f"module-level array {name!r} (bound at line "
                    f"{arrays[name]}) — pass it as an argument; "
                    f"closed-over arrays break multi-process runs"))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        leaf = _call_leaf(node.func)
        if leaf in _DEPRECATED_CALLS and not rel.endswith(_DEF_MODULE):
            out.append(finding(
                "deprecated-spelling", f"{rel}:{node.lineno}",
                f"call to deprecated {leaf}() — use the ExchangeConfig "
                f"spec grammar instead"))
        if leaf not in _KWARG_OK_CALLEES:
            for kw in node.keywords:
                if kw.arg in _DEPRECATED_KWARGS:
                    out.append(finding(
                        "deprecated-spelling", f"{rel}:{node.lineno}",
                        f"deprecated keyword {kw.arg}= in {leaf}() call "
                        f"— fold it into the exchange= spec"))
    return out


def lint_source(root: str) -> list:
    """Run both source rules over every .py under ``root``."""
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in ("__pycache__", ".git")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                path = os.path.join(dirpath, fn)
                out.extend(lint_file(path, os.path.relpath(path, root)))
    return out


@register_rule("jit-module-array", "warning", scope="source")
def rule_jit_module_array(root):
    """Jitted function closes over a module-level jax array (the
    multi-process placement footgun)."""
    return [f for f in lint_source(root) if f.rule == "jit-module-array"]


@register_rule("deprecated-spelling", "warning", scope="source")
def rule_deprecated_spelling(root):
    """Call sites using ReproDeprecationWarning-deprecated spellings."""
    return [f for f in lint_source(root)
            if f.rule == "deprecated-spelling"]
