"""Derive per-round wire traffic from a collective graph.

This is the ONE place HLO bytes become modelled-traffic comparisons:
``bench_drivers``, the analysis rules, and the launch-layer estimators
all call :func:`derived_round_traffic` instead of re-walking HLO text.

Derivations (paper §4's per-algorithm traffic decomposition):

- master-centric transports (``persistent``, ``spark_faithful``,
  ``compressed:*`` on the ``xla`` backend): every worker sends its
  per-worker collective operand up and receives the aggregate back, so
  derived = 2 x K x per-worker operand bytes, excluding the scalar f32
  metric psum (4 bytes) — a convergence probe, not update traffic;
- ``reduce_scatter``: the ring volume — (K-1) x the reduce-scatter
  operand plus K x (K-1) x the all-gather shard operand;
- ``ring`` backend: K x the collective-permute operand bytes (each
  unrolled hop is one ppermute op moved by all K ranks).

``padded_len`` is imported from :mod:`repro.comm.collectives` — the
single owner of the reduce-scatter padding formula (a cross-check test
asserts this module does not grow its own copy).
"""
from __future__ import annotations

from repro.comm.collectives import padded_len  # noqa: F401  (single owner)

from repro.analysis.graph import CollectiveGraph

# the one scalar f32 convergence-metric psum every round carries
SCALAR_METRIC_BYTES = 4

# wire dtypes a quantizing codec may put on the wire
QUANTIZED_DTYPES = ("s8", "u8", "s4", "u4")

# codec name -> the sub-f32 dtype its payload collective must carry
# (None: full-precision f32 is the expected wire format). Packed int4
# AND int2 both travel as u8 bytes (two resp. four codes per byte).
CODEC_WIRE_DTYPE = {"f32": None, "int8": "s8", "int4": "u8", "int2": "u8"}


def codec_wire_dtype(codec: str) -> str | None:
    """Expected sub-f32 wire dtype for ANY codec grammar name.

    The ``ef:`` wrapper changes what gets encoded (delta + residual),
    not the wire format — ``ef:int4`` must show the same u8 all-gather
    as ``int4``. ``topk(r=..)`` ships f32 values + s32 indices, so it
    (like ``f32``) expects no quantized dtype on the wire."""
    return CODEC_WIRE_DTYPE.get(codec.removeprefix("ef:"))


def derived_round_traffic(graph: CollectiveGraph, exchange, K: int) -> int:
    """Bytes/round implied by the compiled HLO for one exchange cell.

    ``exchange`` is a resolved ``ExchangeConfig`` (only ``.backend`` and
    ``.scheme.transport`` are read, so tests can pass any duck)."""
    if K < 2:
        return 0
    if exchange.backend == "ring":
        cp = sum(op.operand_bytes for op in graph.ops("collective-permute"))
        return K * cp
    if exchange.scheme.transport == "reduce_scatter":
        rs = sum(op.operand_bytes for op in graph.ops("reduce-scatter"))
        ag = sum(op.operand_bytes for op in graph.ops("all-gather"))
        return (K - 1) * rs + K * (K - 1) * ag
    payload = sum(op.operand_bytes for op in graph.collectives
                  if not _is_metric_psum(op))
    return 2 * K * payload


def _is_metric_psum(op) -> bool:
    return (op.kind == "all-reduce"
            and op.operand_bytes <= SCALAR_METRIC_BYTES)


def quantized_wire_dtypes(graph: CollectiveGraph) -> set[str]:
    """Sub-f32 dtypes present in payload-moving collectives (all-gather
    and collective-permute ops): s8 for int8, u8 for packed int4."""
    out = set()
    for op in graph.collectives:
        if op.kind not in ("all-gather", "collective-permute"):
            continue
        out.update(dt for dt in op.operand_dtypes
                   if dt in QUANTIZED_DTYPES)
    return out


def payload_collectives(graph: CollectiveGraph) -> tuple:
    """Collectives that move update/state payload (metric psum excluded)."""
    return tuple(op for op in graph.collectives if not _is_metric_psum(op))
