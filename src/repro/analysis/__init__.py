"""Static analysis of compiled exchange cells.

``graph`` lifts optimized HLO into per-op collective records;
``findings`` holds the typed rule registry; ``rules`` (cell scope) and
``pylint_jax`` (source scope) populate it; ``traffic`` is the single
owner of HLO-bytes derivation; ``cells`` defines the analyzable matrix;
``run`` is the ``python -m repro.analysis`` CLI.

Only the pure modules are imported eagerly: ``repro.utils.hlo``
delegates to :mod:`repro.analysis.graph` at package-import time, so
this ``__init__`` must not drag in jax or the rest of ``repro``
(``rules``/``cells``/``traffic`` import lazily via ``__getattr__``).
"""
from repro.analysis.findings import (RULES, Finding, Rule,  # noqa: F401
                                     register_rule)
from repro.analysis.graph import (COLLECTIVE_OPS, CollectiveGraph,  # noqa: F401
                                  CollectiveOp, Shape, lift_hlo)

_LAZY = ("cells", "pylint_jax", "rules", "run", "traffic")


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module 'repro.analysis' has no attribute "
                         f"{name!r}")
