from repro.serve.decode import make_serve_step, greedy_generate  # noqa: F401
