"""Serving: batched prefill + single-token decode steps.

``make_serve_step`` builds the jittable one-token step the decode
dry-run shapes (decode_32k / long_500k) lower: one new token against a
seq_len-long persistent state (KV cache / ring buffer / SSM state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_serve_step(model):
    def serve_step(params, states, tokens, positions):
        logits, states = model.decode_step(
            params, {"tokens": tokens, "positions": positions}, states)
        return logits, states
    return serve_step


def greedy_generate(model, params, prompt_tokens, *, max_new: int = 16,
                    max_len: int | None = None, batch_extras: dict | None = None):
    """Prefill the prompt then greedily decode max_new tokens.

    prompt_tokens: (B, S) int32. Returns (B, max_new) generated ids.
    """
    B, S = prompt_tokens.shape
    if max_new <= 0:
        # honor the contract exactly: no tokens requested, none emitted
        # (the prefill-argmax token below is the FIRST generated token,
        # so emitting it unconditionally used to return one token too
        # many here)
        return jnp.zeros((B, 0), jnp.int32)
    max_len = max_len or (S + max_new)
    extras = batch_extras or {}
    states = model.init_states(params, B, max_len, batch=extras or None)
    logits, states = model.prefill(
        params, {"tokens": prompt_tokens, **extras}, states)
    step = jax.jit(make_serve_step(model))
    tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
    out = [tok]
    for t in range(S, S + max_new - 1):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, states = step(params, states, tok, pos)
        tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
