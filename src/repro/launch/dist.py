"""Real multi-process execution of the sharded driver layer.

The whole repo so far runs one process (vmap virtual workers or
shard_map over local devices). This entry stands up the paper's actual
deployment shape — N communicating processes — via
``jax.distributed.initialize``, and runs the UNCHANGED sharded driver
(``repro.core.distributed.build_sharded_round``) across them: same
algorithms, same :class:`~repro.core.distributed.ExchangeConfig`
surface (including the collective-backend segment), same compiled
round. That makes two things real that were previously simulated:

  * ``calibrate_link`` (``--calibrate``) times the exchange's actual
    collective over a real inter-process transport instead of
    device-to-device copies inside one process, and
  * the paper's framework-gap experiment (same algorithm, different
    fabric) becomes rerunnable: ``--exchange persistent`` vs
    ``--exchange persistent/ring`` on real processes.

Every process runs this same script with the same arguments except
``--process-id``::

    # terminal 1                                      # terminal 2
    python -m repro.launch.dist \\                    ... same ... \\
        --coordinator 127.0.0.1:9876 \\
        --num-processes 2 --process-id 0 \\           --process-id 1 \\
        --algorithm cocoa --exchange persistent \\
        --rounds 5 --out /tmp/r0.json                 --out /tmp/r1.json

With ``--num-processes 1`` (the default) no coordinator is needed and
the run degrades to single-process shard_map over the visible devices
(fake extra CPU devices with ``XLA_FLAGS=--xla_force_host_platform_
device_count=K`` to match a K-process run) — the reference the CI
smoke test pins the 2-process trajectory bit-identical against.

The problem is rebuilt deterministically from ``--seed`` on every
process, so the only cross-process traffic is the driver's own
exchange. Worker count K = the GLOBAL device count (one device per
process on plain CPU hosts). The result JSON records the per-round
primal objectives plus SHA-256 hashes of the final shared/local state,
which is how runs are compared bit-for-bit.
"""
from __future__ import annotations

import argparse
import hashlib
import json


def _global_put(x, mesh, spec):
    """Place a host array on the (possibly multi-process) mesh: every
    process holds the full value, each materializes only its shards.
    (``device_put`` onto cross-process shardings is version-fragile;
    ``make_array_from_callback`` is the portable spelling.)"""
    import jax
    import numpy as np
    from jax.sharding import NamedSharding

    x = np.asarray(x)
    sharding = NamedSharding(mesh, spec)
    return jax.make_array_from_callback(x.shape, sharding,
                                        lambda idx: x[idx])


def _replicate(x, mesh):
    """Re-replicate a sharded global array so every process can read
    (and hash) the full value — an all-gather via output sharding."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.jit(lambda a: a,
                   out_shardings=NamedSharding(mesh, P(None)))(x)


def _sha256(x) -> str:
    import numpy as np

    a = np.ascontiguousarray(np.asarray(x))
    return hashlib.sha256(a.tobytes()).hexdigest()


def build_trainer(args):
    from repro.core.baselines import SGDConfig
    from repro.core.cocoa import CoCoAConfig
    from repro.core.tradeoff import make_trainer
    from repro.data import make_glm_data

    A, b, _ = make_glm_data(m=args.m, n=args.n, density=args.density,
                            zipf_a=1.1, seed=args.seed)
    if args.algorithm == "minibatch_sgd":
        cfg = SGDConfig(K=args.workers, H=args.H, lam=args.lam,
                        step_size=0.1, exchange=args.exchange, seed=0)
    else:
        cfg = CoCoAConfig(K=args.workers, H=args.H, lam=args.lam,
                          solver=args.solver, exchange=args.exchange,
                          seed=0)
    return make_trainer(args.algorithm, cfg, A, b)


def run(args) -> dict:
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import distributed as dist
    from repro.utils import compat

    K = len(jax.devices())
    args.workers = K
    tr = build_trainer(args)
    mesh = compat.make_mesh((K,), ("workers",))
    # the trainer's OWN algorithm object on the generic sharded driver —
    # only the data placement differs from run_sharded: leaves are
    # placed as global arrays so the shards live where the processes are
    data = jax.tree_util.tree_map(
        lambda x: _global_put(x, mesh, P("workers")), tr._data)
    round_fn = dist.build_sharded_round(tr._algo, tr.exchange, data, mesh)
    local, shared = tr.init_state()
    # local may be the (local, codec_state) pair of a stateful codec —
    # every leaf is worker-partitioned, so tree_map the placement
    local = jax.tree_util.tree_map(
        lambda x: _global_put(x, mesh, P("workers")), local)
    shared = jax.tree_util.tree_map(
        lambda x: _global_put(x, mesh, P(None)), shared)

    key = jax.random.key(tr.cfg.seed)
    primals = []
    last_t = 0
    for t in range(args.rounds):
        last_t = t + 1
        key, sub = jax.random.split(key)
        keys = _global_put(round_fn.split_keys(sub), mesh, P("workers"))
        # drive the data-as-argument jitted inner: the host-side wrapper
        # closes over the data, and jit forbids closing over arrays
        # spanning non-addressable devices
        local, shared, primal = round_fn.jitted_data(data, keys, local,
                                                     shared, t + 1)
        primals.append(float(primal))   # replicated -> readable anywhere
    shared = dist.finish_run(round_fn, shared, last_t)
    local = dist.unwrap_local_state(tr.exchange, local)

    result = {
        "workers": K,
        "num_processes": args.num_processes,
        "algorithm": args.algorithm,
        "exchange": tr.exchange.spec,
        "rounds": args.rounds,
        "primals": primals,
        "final_shared_sha256": _sha256(_replicate(shared, mesh)),
        "final_local_sha256": _sha256(_replicate(local, mesh)),
    }
    if args.calibrate:
        from repro.bench.timing import TimingPolicy, calibrate_link

        link = calibrate_link(tr.exchange, mesh=mesh,
                              policy=TimingPolicy(warmup=1, reps=3))
        result["link"] = {"bandwidth_Bps": link.bandwidth_Bps,
                          "latency_s": link.latency_s,
                          "source": link.source}
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="run the sharded driver across real processes")
    ap.add_argument("--coordinator", default="127.0.0.1:9876",
                    help="coordinator host:port (process 0 binds it)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--algorithm", default="cocoa",
                    choices=("cocoa", "minibatch_scd", "minibatch_sgd"))
    ap.add_argument("--exchange", default="persistent", metavar="SPEC",
                    help="full exchange spec incl. backend segment "
                         "(e.g. 'compressed:int4/ring')")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--H", type=int, default=16)
    ap.add_argument("--solver", default="scd_ref")
    ap.add_argument("--m", type=int, default=96)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--density", type=float, default=0.2)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--calibrate", action="store_true",
                    help="also calibrate_link over the real transport")
    ap.add_argument("--out", default=None,
                    help="write the result JSON here (every process "
                         "writes — compare them bit-for-bit)")
    args = ap.parse_args(argv)

    import jax

    if args.num_processes > 1:
        # the gloo CPU collectives client must be selected before
        # initialize(); it is what backs cross-process CPU collectives
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.num_processes,
                                   process_id=args.process_id)

    result = run(args)
    line = json.dumps(result, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(line + "\n")
    print(line)


if __name__ == "__main__":
    main()
