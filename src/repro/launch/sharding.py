"""Sharding rules: param/state/batch PartitionSpecs per architecture.

Tensor parallelism runs over the 16-way ``model`` axis on *feature*
dimensions (they divide 16 for every assigned arch; head counts often
don't — kv=1..8, q=40/6 — so head-dim sharding would force GSPMD padding
everywhere). MoE experts shard on ``model`` (expert parallelism). Batch
shards on (``pod``, ``data``). ``fsdp=True`` additionally shards the
remaining large dim of every >=2-D param over ``data`` (ZeRO-3-style via
GSPMD, used by the >100B configs).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# parent-module name -> role of its "w"
_COL = {"wq", "wk", "wv", "w_up", "w_gate", "w_uq", "w_dkv", "w_kr",
        "w_x", "w_gate_branch", "w_rec_gate", "w_in_gate", "w_in", "proj"}
_ROW = {"wo", "w_down", "w_out"}
_REPL = {"router"}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def _base_spec(names: list[str], ndim: int, dp: tuple,
               tied_embed: bool = False) -> P:
    """Spec ignoring any stacked leading layer dim (ndim = effective)."""
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    grandp = names[-3] if len(names) >= 3 else ""
    if name == "embed":
        # untied: shard the feature dim — the token gather then needs NO
        # collective (vocab-sharded lookup all-reduces a (B,S,d) mask-sum
        # every step). Tied embeddings keep vocab sharding so the
        # unembed matmul stays column-parallel.
        return P("model", None) if tied_embed else P(None, "model")
    if name == "unembed":
        return P(None, "model")
    if name == "dec_pos":
        return P(None, None)
    # MoE expert tensors: (E, d_in, d_out) under channel/
    if name in ("w_up", "w_gate", "w_down") and ndim == 3:
        return P("model", None, None)
    if ndim <= 1:
        return P(*([None] * ndim))
    if parent in _REPL or name in _REPL:
        return P(*([None] * ndim))
    if parent in _COL or (name == "w" and grandp in _COL) or name in _COL:
        return P(*([None] * (ndim - 1)), "model")
    if parent in _ROW or (name == "w" and grandp in _ROW) or name in _ROW:
        return P(*([None] * (ndim - 2)), "model", None)
    if parent == "conv" or name == "conv":
        return P(*([None] * (ndim - 1)), "model")
    return P(*([None] * ndim))


def _apply_fsdp(spec: P, shape, dp_axis: str, data_size: int) -> P:
    """Put the data axis on the first unsharded dim that divides."""
    parts = list(spec)
    for i, (s, dim) in enumerate(zip(parts, shape)):
        if s is None and dim % data_size == 0 and dim >= 1024:
            parts[i] = dp_axis
            break
    return P(*parts)


def param_specs(params, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec tree mirroring ``params``."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    data_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tied_embed = isinstance(params, dict) and "unembed" not in params \
        and "embed" in params

    def spec_of(path, leaf):
        names = _path_names(path)
        ndim = leaf.ndim
        stacked = "stack" in names
        eff = ndim - 1 if stacked else ndim
        base = _base_spec(names, eff, dp, tied_embed)
        parts = ((None,) + tuple(base)) if stacked else tuple(base)
        spec = P(*parts)
        if fsdp and leaf.ndim >= 2:
            spec = _apply_fsdp(spec, leaf.shape, "data", mesh.shape["data"])
        return spec

    return jax.tree_util.tree_map_with_path(spec_of, params)


def batch_specs(batch, mesh: Mesh, *, shard_batch: bool = True):
    """Inputs: batch dim over (pod, data) when it divides; else replicated."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    data_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec_of(path, leaf):
        if not shard_batch or leaf.ndim == 0:
            return P()
        if leaf.shape[0] % data_size == 0:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_of, batch)


def state_specs(states, mesh: Mesh):
    """Decode-state sharding (mirrors models.layers.constrain_cache):
    KV/latent caches shard batch over (pod,data) and cache-sequence over
    "model" (context parallelism); the B=1 long-context decode shards
    the sequence over ALL axes. Recurrent states (h/conv) shard their
    feature dims over "model"."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    data_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tp_size = int(mesh.shape["model"])

    CACHE = ("k", "v", "c", "kr", "pos_abs", "cross_k", "cross_v")

    def spec_of(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0:
            return P()
        shape = leaf.shape
        parts = [None] * leaf.ndim
        name = names[-1] if names else ""
        if name in CACHE:
            if shape[0] == 1 and leaf.ndim >= 2 \
                    and shape[1] % (data_size * tp_size) == 0:
                parts[1] = dp + ("model",)          # B=1: seq over all
            else:
                if shape[0] % data_size == 0 and shape[0] > 1:
                    parts[0] = dp
                if leaf.ndim >= 2 and shape[1] % tp_size == 0:
                    parts[1] = "model"              # cache seq over model
            return P(*parts)
        # recurrent states
        if shape[0] % data_size == 0 and shape[0] > 1:
            parts[0] = dp
        if name == "conv" and leaf.ndim == 3 and shape[2] % tp_size == 0:
            parts[2] = "model"
        if name == "h" and leaf.ndim == 4 and shape[1] % tp_size == 0:
            parts[1] = "model"   # SSD heads
        if name == "h" and leaf.ndim == 2 and shape[1] % tp_size == 0:
            parts[1] = "model"   # RG-LRU width
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_of, states)


def shardings_of(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
