"""Serving launcher: batched prefill + greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.serve import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, min(100, cfg.vocab_size),
                     (args.batch, args.prompt_len)), jnp.int32)
    extras = {}
    if cfg.family == "audio":
        extras["frame_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encdec.source_len,
                                 cfg.d_model)) * 0.02, jnp.bfloat16)
    if cfg.family == "vlm":
        P = 8
        extras["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, P, cfg.d_model)) * 0.02,
            jnp.bfloat16)
        extras["patch_positions"] = jnp.zeros((args.batch, P, 3), jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompts, max_new=args.max_new,
                          batch_extras=extras)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(np.asarray(out))


if __name__ == "__main__":
    main()
