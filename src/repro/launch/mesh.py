"""Production meshes for the TPU v5e target.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — jax locks the device count on first use, and
only the dry-run is allowed to fake 512 host devices.
"""
from __future__ import annotations

from repro.utils import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_worker_mesh(K: int):
    """1-D mesh for the CoCoA shard_map driver."""
    return compat.make_mesh((K,), ("workers",))


# Hardware constants (TPU v5e), used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def kernel_roofline(flops: float, bytes_moved: float,
                    seconds: float) -> dict:
    """Achieved FLOP/s and bytes/s of one kernel cell against the chip
    peaks above — the per-kernel roofline fractions ``bench_kernels``
    reports and ``repro.launch.roofline --kernels`` summarizes. Lives
    here (not roofline.py) so the benchmark can import it without the
    dry-run module's fake-device environment setup."""
    return {
        "achieved_gflops": flops / seconds / 1e9,
        "achieved_gbps": bytes_moved / seconds / 1e9,
        "flops_frac_of_peak": flops / seconds / PEAK_FLOPS_BF16,
        "bw_frac_of_hbm": bytes_moved / seconds / HBM_BW,
    }
