import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis from the compiled dry-run (single-pod mesh).

Methodology (see DESIGN.md §5): `cost_analysis()` counts a `lax.scan`
body ONCE regardless of trip count, so the full-config scan lowering
cannot be read directly. Instead we lower the same model python-UNROLLED
at L1 = prologue + 1 cycle and L2 = prologue + 2 cycles and extrapolate

    cost(L) = cost(L1) + (n_cycles - 1) * (cost(L2) - cost(L1))

which is exact for layer-homogeneous stacks (per-cycle cost is constant;
embed/unembed/loss live in the L1 base term). Collective operand bytes
are parsed from the compiled HLO and extrapolated the same way.

Terms (TPU v5e constants in launch/mesh.py):
    compute    = FLOPs_per_device / 197e12
    memory     = bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9
MODEL_FLOPS = 6*N*D (train, dense), 6*N_active*D (train, MoE), 2*N*D
(+cache reads in the memory term) for decode.
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config  # noqa: E402
from repro.configs.base import padded_vocab  # noqa: E402
from repro.launch import build  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               kernel_roofline, make_production_mesh)
from repro.analysis.graph import lift_hlo  # noqa: E402
from repro.models.transformer import _period, layer_plan  # noqa: E402


def _cost_of(built) -> dict:
    from repro.utils import compat
    compiled = built.lowered.compile()
    ca = compat.cost_analysis(compiled)
    coll = lift_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.total_operand_bytes),
        "peak_bytes": float(mem.argument_size_in_bytes
                            + mem.temp_size_in_bytes
                            - mem.alias_size_in_bytes),
    }


def _unrolled_cfg(cfg, n_cycles: int):
    k_dense = cfg.moe.first_k_dense if cfg.moe else 0
    period = _period(cfg)
    cfg2 = dataclasses.replace(cfg, num_layers=k_dense + n_cycles * period,
                               mtp_depth=0)
    return cfg2


def _lower_unrolled(cfg, shape, mesh, n_cycles):
    from repro.models.layers import set_force_dense_attention
    c = _unrolled_cfg(cfg, n_cycles)
    set_force_dense_attention(True)   # flash scans are cost-counted once
    try:
        if shape.kind == "train":
            return build.lower_train(c, shape, mesh, unroll=True, remat=True,
                                     donate=False, microbatch=1)
        if shape.kind == "prefill":
            return build.lower_prefill(c, shape, mesh, unroll=True)
        return build.lower_decode(c, shape, mesh, unroll=True, donate=False)
    finally:
        set_force_dense_attention(False)


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the full config + shape (global):
    6*N_active*D (train) / 2*N_active*D (inference) for the parametric
    part, plus the analytic attention-score term (which dominates at
    32k+): 4*S_kv*H*Dh per query token per attention layer (halved for
    causal prefill/train, windowed for SWA)."""
    v = padded_vocab(cfg)
    d = cfg.d_model
    n_embed = v * d * (1 if cfg.tie_embeddings else 2)
    plan = layer_plan(cfg)

    # ---- attention-score FLOPs ----
    B, S = shape.global_batch, shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    attn_fl = 0.0
    for mixer, _ in plan:
        if mixer == "attn":
            hd, kv_len = cfg.num_heads * cfg.head_dim, S
            win = cfg.sliding_window
        elif mixer == "attn_local":
            hd = cfg.num_heads * cfg.head_dim
            win = (cfg.rglru.local_window if cfg.rglru
                   else cfg.sliding_window)
        elif mixer == "mla":
            m = cfg.mla
            hd = cfg.num_heads * (m.qk_nope_dim + m.qk_rope_dim
                                  + m.v_head_dim) / 2.0
            win = None
        else:
            continue
        if shape.kind == "decode":
            kv = min(win, S) if win else S
            attn_fl += mult * B * 1 * 4 * kv * hd
        else:
            kv_eff = (min(win, S) if win else S / 2.0)  # causal half
            attn_fl += mult * B * S * 4 * kv_eff * hd

    n_active = 0
    for mixer, channel in plan:
        if mixer in ("attn", "attn_local"):
            kvd = cfg.num_kv_heads * cfg.head_dim
            n_active += d * cfg.num_heads * cfg.head_dim * 2 + 2 * d * kvd
        elif mixer == "mla":
            m = cfg.mla
            n_active += (d * m.q_lora_rank
                         + m.q_lora_rank * cfg.num_heads
                         * (m.qk_nope_dim + m.qk_rope_dim)
                         + d * (m.kv_lora_rank + m.qk_rope_dim)
                         + m.kv_lora_rank * cfg.num_heads
                         * (m.qk_nope_dim + m.v_head_dim)
                         + cfg.num_heads * m.v_head_dim * d)
        elif mixer == "rglru":
            w = cfg.rglru.lru_width or d
            n_active += 2 * d * w + 2 * w * w + w * d
        elif mixer == "ssd":
            s = cfg.ssm
            din = s.d_inner(d)
            n_active += d * (2 * din + 2 * s.n_groups * s.d_state
                             + s.n_heads(d)) + din * d
        if channel == "mlp":
            n_active += d * cfg.d_ff * (3 if cfg.mlp_gated else 2)
        elif channel == "moe":
            mo = cfg.moe
            n_active += (mo.top_k + mo.num_shared) * d * mo.d_expert * 3
    if cfg.family == "audio":
        n_active *= 1.6  # cross-attention + encoder stack, rough
    n_total = n_active + n_embed
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    par_mult = 6 if shape.kind == "train" else 2
    return par_mult * n_total * tokens + attn_fl


def roofline_pair(arch: str, shape_name: str, *, chips: int = 256) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not build.supported(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped"}
    mesh = make_production_mesh(multi_pod=False)
    cfg_v = build.shape_variant(cfg, shape)
    if cfg_v.family == "audio":
        # whisper is python-unrolled already: lower the full config once
        built = build.lower_pair(arch, shape_name, mesh)
        cost = _cost_of(built)
    else:
        k_dense = cfg_v.moe.first_k_dense if cfg_v.moe else 0
        period = _period(cfg_v)
        n_cycles = (cfg_v.num_layers - k_dense) // period
        c1 = _cost_of(_lower_unrolled(cfg_v, shape, mesh, 1))
        c2 = _cost_of(_lower_unrolled(cfg_v, shape, mesh, 2))
        cost = {k: c1[k] + (n_cycles - 1) * (c2[k] - c1[k])
                for k in ("flops", "bytes", "coll_bytes")}
        cost["peak_bytes"] = c1["peak_bytes"]  # L1 peak, indicative only
        if shape.kind == "train":
            # roofline lowers microbatch=1; the production step uses the
            # same total tokens, so per-step cost is identical.
            pass
    t_compute = cost["flops"] / PEAK_FLOPS_BF16
    t_memory = cost["bytes"] / HBM_BW
    t_coll = cost["coll_bytes"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg_v, shape)
    hlo_total = cost["flops"] * chips
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "kind": shape.kind, "chips": chips,
        "hlo_flops_per_dev": cost["flops"],
        "hlo_bytes_per_dev": cost["bytes"],
        "coll_bytes_per_dev": cost["coll_bytes"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(hlo_total, 1.0),
        "bound_step_time_s": round(max(terms.values()), 6),
    }
    return rec


def kernel_roofline_summary(bench: dict) -> dict:
    """Per-kernel roofline fractions from a BENCH_kernels.json dict:
    every ``model_flops_<cell>`` counter is paired with its
    ``model_bytes_<cell>`` twin and the cell's measured time, and
    reported as achieved FLOP/s and bytes/s against the chip peaks.
    The models are machine-independent (exact-gated in CI); the
    fractions carry whatever the timing host achieved — interpret-mode
    CPU numbers in CI, real kernel numbers on TPU."""
    counters = bench.get("counters", {})
    timings = bench.get("timings_s", {})
    cells = {}
    for name, fl in sorted(counters.items()):
        if not name.startswith("model_flops_"):
            continue
        cell = name[len("model_flops_"):]
        nbytes = counters.get(f"model_bytes_{cell}")
        t = timings.get(cell)
        if nbytes is None or not t:
            continue
        cells[cell] = {
            "time_s": t,
            "model_flops": float(fl),
            "model_bytes": float(nbytes),
            **kernel_roofline(float(fl), float(nbytes), float(t)),
        }
    return {"peaks": {"flops_bf16_per_s": PEAK_FLOPS_BF16,
                      "hbm_bytes_per_s": HBM_BW},
            "cells": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--kernels", metavar="BENCH_KERNELS_JSON",
                    help="write a per-kernel roofline-fraction summary "
                         "for a BENCH_kernels.json (to --out as a file) "
                         "instead of the transformer dry-run")
    args = ap.parse_args()
    if args.kernels:
        with open(args.kernels) as f:
            bench = json.load(f)
        summary = kernel_roofline_summary(bench)
        out = args.out
        if os.path.isdir(out) or out.endswith(os.sep):
            os.makedirs(out, exist_ok=True)
            out = os.path.join(out, "ROOFLINE_kernels.json")
        else:
            os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        for cell, rec in summary["cells"].items():
            print(f"{cell:44s} {rec['achieved_gflops']:8.3f} GFLOP/s "
                  f"({rec['flops_frac_of_peak']:.2e} of peak)  "
                  f"{rec['achieved_gbps']:8.3f} GB/s "
                  f"({rec['bw_frac_of_hbm']:.2e} of HBM)")
        print(f"# -> {out}")
        return
    pairs = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in pairs:
        try:
            rec = roofline_pair(arch, shape)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "fail",
                   "error": f"{type(e).__name__}: {e}"}
        with open(os.path.join(args.out, f"{arch}_{shape}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            print(f"{arch:28s} {shape:12s} comp {rec['compute_s']:9.4f}s "
                  f"mem {rec['memory_s']:9.4f}s coll {rec['collective_s']:9.4f}s"
                  f" -> {rec['dominant']:10s} useful={rec['useful_flops_ratio']:.2f}")
        else:
            print(f"{arch:28s} {shape:12s} {rec['status']}")


if __name__ == "__main__":
    main()
