import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) combination
lowers AND compiles on the production meshes, and record memory / cost /
collective-traffic analysis for §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.analysis.graph import lift_hlo  # noqa: E402
from repro.configs import ARCHS, SHAPES, get_config  # noqa: E402
from repro.launch import build  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_pair(arch: str, shape: str, *, multi_pod: bool, verbose: bool = True,
             **kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    built = build.lower_pair(arch, shape, mesh, **kw)
    if built is None:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "long_context=skip (see DESIGN.md §6)"}
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = built.lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax < 0.5 returns a one-element list
        cost = cost[0] if cost else {}
    coll = lift_hlo(compiled.as_text())
    n_dev = mesh.devices.size
    rec = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "kind": built.kind, "status": "ok", "notes": built.notes,
        "devices": n_dev,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "per_device_bytes": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "peak_live": (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes
                          - mem.alias_size_in_bytes),
        },
        "hlo_flops": cost.get("flops", 0.0),
        "hlo_bytes": cost.get("bytes accessed", 0.0),
        "collectives": {k: {"count": v[0], "operand_bytes": v[1],
                            "result_bytes": v[2]}
                        for k, v in coll.by_kind().items()},
        "collective_operand_bytes": coll.total_operand_bytes,
    }
    if verbose:
        gb = 1 << 30
        print(f"[{arch} x {shape} | {'2x16x16' if multi_pod else '16x16'} "
              f"| {built.kind}] lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  per-device: args {mem.argument_size_in_bytes/gb:.2f} GiB, "
              f"temps {mem.temp_size_in_bytes/gb:.2f} GiB, "
              f"aliased {mem.alias_size_in_bytes/gb:.2f} GiB")
        print(f"  HLO flops/device {cost.get('flops', 0):.3e}  "
              f"bytes/device {cost.get('bytes accessed', 0):.3e}")
        print("  " + coll.summary().replace("\n", "\n  "))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    pairs = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in pairs:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'mp' if mp else 'sp'}"
            try:
                rec = run_pair(arch, shape, multi_pod=mp)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "fail", "error": f"{type(e).__name__}: {e}"}
                failures.append(tag)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("all dry-runs OK")


if __name__ == "__main__":
    main()
