"""Shared builders for dry-run / roofline: abstract params, shardings,
and lowered step functions for every (arch x shape x mesh) combination.

No jax device state is touched at import time; callers (dryrun.py,
roofline.py) set XLA_FLAGS before importing anything.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

import contextlib

from repro.configs import ModelConfig, ShapeConfig, SHAPES, get_config, input_specs
from repro.configs.base import padded_vocab
from repro.launch import sharding as sh
from repro.models.layers import set_partitioning
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.serve import make_serve_step
from repro.train import make_train_step

# archs where a 500k-token full-attention decode is impossible and a
# sliding window is substituted (cfg.long_context == "swa")
LONG_WINDOW = 8192


@contextlib.contextmanager
def partitioning(mesh):
    """Bind the models' logical activation axes to this mesh + enter the
    mesh context so with_sharding_constraint resolves axis names."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    set_partitioning(dp=dp, tp="model", mesh=mesh)
    try:
        with mesh:
            yield
    finally:
        set_partitioning(None, None)


@dataclass
class Built:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    lowered: Any
    kind: str
    notes: dict


def shape_variant(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Adjust the arch config for a given input shape (SWA for 500k)."""
    if shape.name == "long_500k" and cfg.long_context == "swa":
        cfg = dataclasses.replace(cfg, sliding_window=LONG_WINDOW)
    return cfg


def supported(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and cfg.long_context == "skip":
        return False
    return True


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    """bf16 optimizer state for the >100B configs (memory notes in
    EXPERIMENTS.md); f32 elsewhere."""
    big = cfg.moe is not None or cfg.d_model >= 8192
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")


def abstract_train_args(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        *, fsdp: bool):
    model = build_model(cfg)
    opt_cfg = opt_config_for(cfg)
    params_s = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    opt_s = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_s)
    batch_s = dict(input_specs(cfg, shape))
    p_specs = sh.param_specs(params_s, mesh, fsdp=fsdp)
    o_specs = {
        "mu": sh.param_specs(opt_s["mu"], mesh, fsdp=fsdp),
        "nu": sh.param_specs(opt_s["nu"], mesh, fsdp=fsdp),
        "count": P(),
    }
    b_specs = sh.batch_specs(batch_s, mesh)
    return model, opt_cfg, (params_s, opt_s, batch_s), (p_specs, o_specs, b_specs)


def lower_train(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                fsdp: bool | None = None, remat: bool = True,
                unroll: bool = False, donate: bool = True,
                microbatch: int | None = None):
    cfg = shape_variant(cfg, shape)
    if fsdp is None:
        fsdp = cfg.moe is not None or cfg.d_model >= 6144
    if microbatch is None:
        # gradient accumulation for the activation-heavy giants
        microbatch = 4 if (cfg.moe is not None or cfg.d_model >= 7168) else 1
    model, opt_cfg, (params_s, opt_s, batch_s), (p_sp, o_sp, b_sp) = \
        abstract_train_args(cfg, shape, mesh, fsdp=fsdp)
    step = make_train_step(model, opt_cfg, remat=remat, unroll=unroll,
                           microbatch=microbatch)
    jit_kw = dict(
        in_shardings=(sh.shardings_of(p_sp, mesh),
                      sh.shardings_of(o_sp, mesh),
                      sh.shardings_of(b_sp, mesh)),
        out_shardings=(sh.shardings_of(p_sp, mesh),
                       sh.shardings_of(o_sp, mesh), None),
    )
    if donate:
        jit_kw["donate_argnums"] = (0, 1)
    with partitioning(mesh):
        lowered = jax.jit(step, **jit_kw).lower(params_s, opt_s, batch_s)
    return Built(cfg, shape, mesh, lowered, "train",
                 {"fsdp": fsdp, "remat": remat, "microbatch": microbatch,
                  "opt_dtype": opt_cfg.state_dtype})


def lower_train_local_updates(cfg: ModelConfig, shape: ShapeConfig,
                              mesh: Mesh, *, H: int, remat: bool = True):
    """The paper's technique at transformer scale: H local optimizer
    steps per parameter synchronization (local-SGD-style), expressed as
    a partial-manual shard_map over the data axes ("model" stays a GSPMD
    auto axis). Collective traffic for parameter sync drops ~1/H.
    """
    from repro.optim.local_updates import LocalUpdatesConfig, local_updates_round

    cfg = shape_variant(cfg, shape)
    model, opt_cfg, (params_s, opt_s, batch_s), (p_sp, o_sp, b_sp) = \
        abstract_train_args(cfg, shape, mesh, fsdp=False)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    # H stacked microbatches; each data shard consumes its slice of each
    batch_H = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((H, *s.shape), s.dtype), batch_s)
    step = make_train_step(model, opt_cfg, remat=remat, grad_sync_axis=None)
    lu_cfg = LocalUpdatesConfig(H=H)

    def shard_fn(params, opt_state, batches):
        params, opt_state, metrics = local_updates_round(
            step, params, opt_state, batches, lu_cfg, dp)
        return params, opt_state, jax.tree.map(lambda m: m[-1], metrics)

    # manual over the data axes only; "model" remains auto/GSPMD
    import jax as _jax
    p_manual = _jax.tree.map(lambda s: P(), params_s,
                             is_leaf=lambda x: hasattr(x, "shape"))
    o_manual = _jax.tree.map(lambda s: P(), opt_s,
                             is_leaf=lambda x: hasattr(x, "shape"))
    b_manual = _jax.tree.map(
        lambda s: P(None, dp, *([None] * (len(s.shape) - 2))), batch_H,
        is_leaf=lambda x: hasattr(x, "shape"))
    fn = jax.shard_map(shard_fn, mesh=mesh, axis_names=set(dp),
                       in_specs=(p_manual, o_manual, b_manual),
                       out_specs=(p_manual, o_manual, P()),
                       check_vma=False)

    jit_kw = dict(
        in_shardings=(sh.shardings_of(p_sp, mesh),
                      sh.shardings_of(o_sp, mesh), None),
        out_shardings=(sh.shardings_of(p_sp, mesh),
                       sh.shardings_of(o_sp, mesh), None),
        donate_argnums=(0, 1),
    )
    with partitioning(mesh):
        lowered = jax.jit(fn, **jit_kw).lower(params_s, opt_s, batch_H)
    return Built(cfg, shape, mesh, lowered, "train_localH",
                 {"H": H, "remat": remat})


def abstract_decode_args(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    model = build_model(cfg)
    params_s = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    B, S = shape.global_batch, shape.seq_len
    max_len = S if cfg.sliding_window is None else min(S, cfg.sliding_window)
    specs = input_specs(cfg, shape)
    if cfg.family == "audio":
        enc_batch = {"frame_embeds": jax.ShapeDtypeStruct(
            (B, cfg.encdec.source_len, cfg.d_model), jnp.bfloat16)}
        states_s = jax.eval_shape(
            lambda p, b: model.init_states(p, B, max_len, batch=b),
            params_s, enc_batch)
    else:
        states_s = jax.eval_shape(
            lambda: model.init_states(None, B, max_len))
    tokens_s = specs["tokens"]
    pos_s = specs["positions"]
    return model, params_s, states_s, tokens_s, pos_s


def lower_decode(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                 unroll: bool = False, donate: bool = True,
                 fsdp: bool | None = None):
    cfg = shape_variant(cfg, shape)
    model, params_s, states_s, tokens_s, pos_s = \
        abstract_decode_args(cfg, shape, mesh)
    if fsdp is None:
        # >100B params don't fit 16-way model sharding at 2 bytes/param;
        # shard weights over the data axes too (weight-gathered serving).
        fsdp = cfg.moe is not None
    p_sp = sh.param_specs(params_s, mesh, fsdp=fsdp)
    s_sp = sh.state_specs(states_s, mesh)
    t_sp = sh.batch_specs({"t": tokens_s, "p": pos_s}, mesh)

    if unroll:
        def serve_step(params, states, tokens, positions):
            from repro.models import transformer as T
            logits, states, _ = T.forward(
                params, cfg, {"tokens": tokens, "positions": positions},
                mode="step", states=states, unroll=True)
            return logits, states
    else:
        serve_step = make_serve_step(model)
    jit_kw = dict(
        in_shardings=(sh.shardings_of(p_sp, mesh),
                      sh.shardings_of(s_sp, mesh),
                      sh.shardings_of(t_sp["t"], mesh),
                      sh.shardings_of(t_sp["p"], mesh)),
        out_shardings=(None, sh.shardings_of(s_sp, mesh)),
    )
    if donate:
        jit_kw["donate_argnums"] = (1,)
    with partitioning(mesh):
        lowered = jax.jit(serve_step, **jit_kw).lower(
            params_s, states_s, tokens_s, pos_s)
    return Built(cfg, shape, mesh, lowered, "decode", {})


def lower_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                  donate: bool = True, unroll: bool = False,
                  fsdp: bool | None = None):
    cfg = shape_variant(cfg, shape)
    model = build_model(cfg)
    params_s = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    batch_s = dict(input_specs(cfg, shape))
    if fsdp is None:
        fsdp = cfg.moe is not None  # weight-gathered serving for >100B
    p_sp = sh.param_specs(params_s, mesh, fsdp=fsdp)
    b_sp = sh.batch_specs(batch_s, mesh)

    def prefill(params, batch):
        B = batch["tokens"].shape[0]
        S = batch["tokens"].shape[1]
        states = model.init_states(params, B, S, batch=batch
                                   if cfg.family == "audio" else None)
        # serving needs only the last-position logits; skipping the full
        # (B,S,V) unembed saves tens of GB at 32k prefill
        return model.prefill(params, batch, states, last_logits_only=True,
                             unroll=unroll)

    out_s = jax.eval_shape(prefill, params_s, batch_s)
    s_sp = sh.state_specs(out_s[1], mesh)
    with partitioning(mesh):
        lowered = jax.jit(
            prefill,
            in_shardings=(sh.shardings_of(p_sp, mesh),
                          sh.shardings_of(b_sp, mesh)),
            out_shardings=(None, sh.shardings_of(s_sp, mesh)),
        ).lower(params_s, batch_s)
    return Built(cfg, shape, mesh, lowered, "prefill", {})


def lower_pair(arch: str, shape_name: str, mesh: Mesh, **kw) -> Built | None:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not supported(cfg, shape):
        return None
    if shape.kind == "train":
        return lower_train(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh)
    return lower_decode(cfg, shape, mesh)
