"""Training launcher: real training on the available devices, or
--dry-run for the production-mesh lowering.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 200 --batch 8 --seq 256 [--local-H 4] [--codec int8]

On this CPU container use --reduced; on a real TPU slice the full config
shards according to launch/sharding.py. --local-H enables the paper's
communication-avoiding local-update rounds (H optimizer steps per
parameter sync) with the roofline-driven default when set to 0.

--exchange takes a full driver-layer exchange spec (e.g.
``compressed:int4`` or ``compressed:int8/straggler:det(slow=4)``) and
uses its wire codec for the delta exchange; --codec remains as the
deprecated single-knob spelling (f32 exact pmean, int8/int4 the
compressed exchange — active when the round runs over a data-parallel
mesh axis).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data.tokens import TokenStream
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.optim.local_updates import LocalUpdatesConfig, local_updates_round, suggest_H
from repro.train import make_train_step
from repro.checkpoint import save_checkpoint


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--local-H", type=int, default=None,
                    help="local steps per sync (paper's knob); 0=auto")
    ap.add_argument("--exchange", default=None, metavar="SPEC",
                    help="driver-layer exchange spec (e.g. "
                         "'compressed:int8' or 'compressed:int4/ring'); "
                         "its wire codec drives the delta exchange (the "
                         "backend segment matters on the sharded "
                         "driver / launch.dist)")
    ap.add_argument("--codec",
                    choices=("f32", "int8", "int4", "int2", "topk",
                             "ef:int8", "ef:int4", "ef:int2", "ef:topk"),
                    default=None,
                    help="DEPRECATED: wire codec alone — use "
                         "--exchange compressed:<codec>")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    # fold the deprecated --codec spelling into the --exchange spec
    from repro.core.distributed import ExchangeConfig
    from repro.utils.deprecation import warn_deprecated

    if args.exchange is not None:
        ex = ExchangeConfig.parse(args.exchange)
        codec = ex.scheme.codec.name
        if args.codec is not None and args.codec != codec:
            raise SystemExit(f"--codec {args.codec} conflicts with "
                             f"--exchange {args.exchange!r} (codec "
                             f"{codec}); drop the deprecated --codec")
    else:
        if args.codec is not None:
            warn_deprecated("--codec is deprecated; use "
                            "--exchange compressed:<codec>")
        codec = args.codec or "f32"
    args.codec = codec

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = AdamWConfig(lr=args.lr)
    opt = adamw_init(params, opt_cfg)
    ts = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)

    H = args.local_H
    if H == 0:
        H = suggest_H(t_compute_per_step=1.0, t_collective_per_sync=0.5)
        print(f"auto-selected local H = {H}")
    if H and H > 1:
        step_local = make_train_step(model, opt_cfg)
        lu_cfg = LocalUpdatesConfig(H=H, codec=args.codec)
        if args.codec != "f32":
            from repro.optim import delta_wire_bytes
            K = max(len(jax.devices()), 1)
            print(f"delta exchange codec={args.codec}: "
                  f"~{delta_wire_bytes(params, lu_cfg, K) / 1e6:.2f} MB "
                  f"modelled per sync across {K} shard(s) "
                  f"(vs {delta_wire_bytes(params, LocalUpdatesConfig(H=H), K) / 1e6:.2f} MB f32)")

        @jax.jit
        def round_fn(params, opt, batches):
            return local_updates_round(step_local, params, opt, batches,
                                       lu_cfg, None)

        n_rounds = args.steps // H
        t0 = time.time()
        for r in range(n_rounds):
            bs = [ts.next_batch() for _ in range(H)]
            batches = {k: jnp.stack([jnp.asarray(b[k]) for b in bs])
                       for k in bs[0]}
            params, opt, ms = round_fn(params, opt, batches)
            print(f"round {r} (H={H}) loss={float(ms['loss'][-1]):.4f} "
                  f"({time.time() - t0:.1f}s)")
    else:
        step = jax.jit(make_train_step(model, opt_cfg))
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in ts.next_batch().items()}
            params, opt, m = step(params, opt, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(m['loss']):.4f} "
                      f"acc={float(m['accuracy']):.3f} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"({time.time() - t0:.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "opt": opt},
                        step=args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
