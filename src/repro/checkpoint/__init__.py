from repro.checkpoint.np_ckpt import save_checkpoint, restore_checkpoint  # noqa: F401
