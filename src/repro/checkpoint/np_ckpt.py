"""Flat-file checkpointing: pytree <-> .npz (+ structure manifest).

Arrays are keyed by their pytree path; bf16 (unsupported by numpy) is
stored as uint16 bit patterns with a dtype tag. Works for params,
optimizer state, and data-pipeline state alike. Atomic via tmp+rename.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(path: str, tree, step: int | None = None) -> None:
    flat, _ = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            a = a.view(np.uint16)
        else:
            dtypes[k] = str(a.dtype)
        arrays[k] = a
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **arrays)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    with open(path + ".meta.json", "w") as f:
        json.dump({"dtypes": dtypes, "step": step}, f)


def restore_checkpoint(path: str, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        a = data[key]
        if meta["dtypes"][key] == "bfloat16":
            a = a.view(np.uint16).astype(np.uint16)
            arr = jnp.asarray(a).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(a)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), \
        meta.get("step")
