"""Language-model losses: CE (+ z-loss) + MoE aux + optional MTP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 z_loss_coef: float = 1e-4):
    """Mean next-token CE over valid positions; labels = -100 masked.
    Returns (loss, metrics)."""
    valid = labels >= 0
    labels_safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_safe[..., None], -1)[..., 0] - logz
    n = jnp.maximum(valid.sum(), 1)
    ce = -(ll * valid).sum() / n
    zl = z_loss_coef * ((logz ** 2) * valid).sum() / n
    acc = ((logits.argmax(-1) == labels_safe) & valid).sum() / n
    return ce + zl, {"ce": ce, "z_loss": zl, "accuracy": acc}


def lm_loss(model, params, batch, *, z_loss_coef: float = 1e-4,
            mtp_coef: float = 0.3, unroll: bool = False, remat: bool = False):
    """Full train loss for any registry model. batch needs tokens+labels
    (labels already shifted; -100 = ignore)."""
    cfg = model.cfg
    if cfg.mtp_depth > 0:
        logits, mtp_logits, aux = model.forward_train_mtp(
            params, batch, unroll=unroll, remat=remat)
        loss, metrics = softmax_xent(logits, batch["labels"], z_loss_coef)
        # MTP predicts token t+2 from position t (labels shifted one more)
        mtp_labels = jnp.concatenate(
            [batch["labels"][:, 1:],
             jnp.full_like(batch["labels"][:, :0], -100)], 1)[:, : mtp_logits.shape[1]]
        mtp_loss, _ = softmax_xent(mtp_logits, mtp_labels, 0.0)
        loss = loss + mtp_coef * mtp_loss + aux
        metrics["mtp_loss"] = mtp_loss
    else:
        logits, aux = model.forward_train(params, batch, unroll=unroll,
                                          remat=remat)
        loss, metrics = softmax_xent(logits, batch["labels"], z_loss_coef)
        loss = loss + aux
    metrics["aux_loss"] = aux if cfg.mtp_depth == 0 else metrics.get(
        "aux_loss", aux)
    metrics["loss"] = loss
    return loss, metrics
