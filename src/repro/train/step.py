"""Train-step factory: loss + grad + optimizer, with optional remat,
gradient accumulation (microbatching), and optional explicit gradient
synchronization (turned off inside local-update rounds).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.schedules import cosine_schedule
from repro.train.loss import lm_loss


def make_train_step(model, opt_cfg: AdamWConfig, *, remat: bool = False,
                    grad_sync_axis: str | None = None,
                    schedule: Callable | None = None,
                    unroll: bool = False, microbatch: int | None = None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_sync_axis: lax.pmean axis for gradients (None = no sync; GSPMD
    pjit paths get their reduction from sharding propagation instead).
    microbatch: gradient-accumulate over N sequential microbatches (the
    global batch's leading dim is split N ways) — divides activation
    memory by ~N at the cost of N sequential passes.
    """
    # remat is applied per layer-cycle inside the model forward (the
    # standard policy) — wrapping the whole loss would save nothing.
    loss_fn = functools.partial(lm_loss, model, unroll=unroll, remat=remat)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            mb = jax.tree.map(
                lambda x: x.reshape(microbatch, x.shape[0] // microbatch,
                                    *x.shape[1:]), batch)

            def accum(g_acc, b):
                (loss, metrics), g = grads_of(params, b)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype) / microbatch,
                    g_acc, g)
                metrics["loss"] = loss
                return g_acc, metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            grads, ms = lax.scan(accum, g0, mb)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), ms)
            loss = metrics["loss"]
        else:
            (loss, metrics), grads = grads_of(params, batch)
        if grad_sync_axis is not None:
            grads = lax.pmean(grads, grad_sync_axis)
        step_no = opt_state["count"] + 1
        lr_scale = (schedule(step_no) if schedule is not None
                    else cosine_schedule(step_no))
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, lr_scale)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["lr_scale"] = lr_scale
        return params, opt_state, metrics

    return step
