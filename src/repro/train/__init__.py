from repro.train.loss import lm_loss  # noqa: F401
from repro.train.step import make_train_step  # noqa: F401
